//! `nahas` — the NAHAS coordinator CLI (leader entrypoint).
//!
//! Subcommands:
//!   simulate       cost every Table-3 baseline (or random samples) on a hw config
//!   search         multi-trial joint / platform-aware / HAS-only search
//!   sweep          concurrent multi-scenario sweep over one shared eval broker
//!   scenarios      list the registered scenario substrates (sweep --scenario)
//!   phase          phase-based (HAS-then-NAS) search (Fig. 9 ablation)
//!   oneshot        weight-sharing search on the AOT proxy supernet
//!   train-child    train one proxy child end-to-end through PJRT
//!   costmodel      generate simulator-labelled data, train + evaluate the MLP
//!   serve          run the simulator service (newline-JSON over TCP)
//!   cluster        queue a join/leave for a live cluster pool (elastic membership)
//!   cluster-status probe health + cache hit counts of a `--hosts` pool
//!
//! Run `nahas help` for flags. clap is not vendored in this offline
//! build; flags are simple `--key value` pairs.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use nahas::accel::{simulate_network, AcceleratorConfig};
use nahas::bench::Table;
use nahas::cluster::{
    membership, probe_host, probe_wire, query_host_stats, MembershipCmd, MembershipLog,
    ShardedEvaluator, WarmSource,
};
use nahas::costmodel::{self, CostModel};
use nahas::has::HasSpace;
use nahas::metrics;
use nahas::nas::{baselines, NasSpace, NasSpaceId};
use nahas::runtime::Runtime;
use nahas::search::joint::JointLayout;
use nahas::search::oneshot::{oneshot_search, BrokerOracle, OneshotCfg};
use nahas::search::phase::phase_search;
use nahas::search::ppo::PpoController;
use nahas::search::reinforce::ReinforceController;
use nahas::search::store::{
    eval_cache_file, eval_cache_file_tasks, eval_fingerprint, eval_fingerprint_tasks,
    serve_fingerprint,
};
use nahas::search::{
    builtin_registry, compile_substrates, evolution::EvolutionController, joint_search,
    run_sweep_observed, scenario_grid, BrokerSnapshot, CacheStore, CacheValue, Controller,
    CostObjective, EvalBroker, Evaluator, MultiTaskEval, ParallelSim, RandomController,
    RewardCfg, Scenario, SearchCfg, SubstrateParams, SurrogateSim, SweepCheckpoint,
    SweepDriver, SweepProgress, Task,
};
use nahas::service::{ServeCache, Server, ServerOpts, ServiceEvaluator, Wire};
use nahas::trainer::ProxyTrainer;
use nahas::util::Rng;

/// Parsed `--key value` flags after the subcommand.
struct Flags(BTreeMap<String, String>);

impl Flags {
    fn parse(args: &[String]) -> Result<Flags> {
        let mut m = BTreeMap::new();
        let mut i = 0;
        while i < args.len() {
            let k = args[i]
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got '{}'", args[i]))?;
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                m.insert(k.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                m.insert(k.to_string(), "true".to_string());
                i += 1;
            }
        }
        Ok(Flags(m))
    }

    fn get(&self, k: &str) -> Option<&str> {
        self.0.get(k).map(|s| s.as_str())
    }

    fn usize(&self, k: &str, default: usize) -> Result<usize> {
        self.get(k).map_or(Ok(default), |v| {
            v.parse().with_context(|| format!("--{k} must be an integer"))
        })
    }

    fn f64(&self, k: &str, default: f64) -> Result<f64> {
        self.get(k)
            .map_or(Ok(default), |v| v.parse().with_context(|| format!("--{k} must be a number")))
    }

    fn u64(&self, k: &str, default: u64) -> Result<u64> {
        self.get(k).map_or(Ok(default), |v| {
            v.parse().with_context(|| format!("--{k} must be an integer"))
        })
    }

    fn bool(&self, k: &str) -> bool {
        self.get(k) == Some("true")
    }
}

fn space_arg(flags: &Flags) -> Result<NasSpace> {
    let name = flags.get("space").unwrap_or("s2");
    let id = match name {
        "s1" | "mobilenetv2" => NasSpaceId::MobileNetV2,
        "s2" | "efficientnet" => NasSpaceId::EfficientNet,
        "s3" | "evolved" => NasSpaceId::Evolved,
        "proxy" => NasSpaceId::Proxy,
        other => bail!("unknown space '{other}' (s1|s2|s3|proxy)"),
    };
    Ok(NasSpace::new(id))
}

/// `--workers N`: evaluation fan-out (defaults to the machine's
/// available parallelism).
fn workers_arg(flags: &Flags) -> Result<usize> {
    let default = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    Ok(flags.usize("workers", default)?.max(1))
}

/// `--hosts a:7878,b:7878=2,...`: the cluster tier's service pool,
/// with an optional `=WEIGHT` per host (default 1; heterogeneous pools
/// shard proportionally to weight). Duplicate addresses are dropped —
/// a repeated address would get two ring entries with identical scores
/// (one of them permanently idle) and corrupt the by-address per-host
/// stats matching.
fn hosts_arg(raw: &str) -> Result<Vec<(String, f64)>> {
    let mut hosts: Vec<(String, f64)> = Vec::new();
    for h in raw.split(',').map(str::trim).filter(|h| !h.is_empty()) {
        let (addr, weight) = match h.split_once('=') {
            Some((a, w)) => {
                let weight: f64 = w
                    .trim()
                    .parse()
                    .with_context(|| format!("--hosts: bad weight '{w}' for {a}"))?;
                if !weight.is_finite() || weight <= 0.0 {
                    bail!("--hosts: weight for {a} must be a positive number");
                }
                (a.trim(), weight)
            }
            None => (h, 1.0),
        };
        // An exactly redundant entry is dropped; a conflicting
        // re-weight is an operator error, not a tiebreak.
        match hosts.iter().position(|(e, _)| e == addr) {
            Some(i) if hosts[i].1 != weight => {
                let w = hosts[i].1;
                bail!("--hosts lists {addr} twice with different weights ({w} vs {weight})")
            }
            Some(_) => {}
            None => hosts.push((addr.to_string(), weight)),
        }
    }
    if hosts.is_empty() {
        bail!("--hosts needs at least one ADDR:PORT[=WEIGHT]");
    }
    Ok(hosts)
}

/// `--wire json|binary`: wire protocol preference for the remote
/// tiers. `binary` (the default) sends a versioned hello at connect
/// and upgrades to the length-prefixed binary frame protocol when the
/// server acks it, falling back per host to the JSON line protocol
/// against servers that predate the hello; `json` forces the line
/// protocol everywhere. Results are bit-identical either way — the
/// codec only changes how the same numbers travel.
fn wire_arg(flags: &Flags) -> Result<Wire> {
    match flags.get("wire").unwrap_or("binary") {
        "binary" | "bin" => Ok(Wire::Binary),
        "json" => Ok(Wire::Json),
        other => bail!("unknown wire protocol '{other}' (json|binary)"),
    }
}

/// `--cache-dir DIR`: open (or create) the persistent cross-run
/// evaluation cache for this run's evaluation context. One file per
/// (space, task, seed) fingerprint, so differently-configured runs
/// coexist in one directory; a stale or damaged file is discarded with
/// a notice and the run proceeds cold.
fn cache_store_arg(
    flags: &Flags,
    space: NasSpaceId,
    seg: bool,
    seed: u64,
) -> Result<Option<CacheStore>> {
    let Some(dir) = flags.get("cache-dir") else {
        return Ok(None);
    };
    let task = if seg { Task::Segmentation } else { Task::Classification };
    let path = eval_cache_file(Path::new(dir), space, task, seed);
    let store = CacheStore::open(&path, &eval_fingerprint(space, task, seed))?;
    report_cache_store(&store);
    Ok(Some(store))
}

/// One-line warm-start / discard report for a freshly opened cache
/// store (shared by the search-side `--cache-dir` and `nahas serve
/// --cache-dir`).
fn report_cache_store<V: CacheValue>(store: &CacheStore<V>) {
    match store.discarded() {
        Some(why) => println!(
            "persistent cache {}: stale contents discarded ({why}); cold start",
            store.path().display()
        ),
        None => println!(
            "persistent cache {}: {} entries loaded",
            store.path().display(),
            store.loaded_len()
        ),
    }
}

/// `--evaluator local|parallel|service|cluster` (+ `--workers`,
/// `--seg`, `--remote ADDR`, `--hosts A,B=2,...`). `--remote` without
/// `--evaluator` implies the batched service client, preserving the
/// old flag's meaning; `--hosts` likewise implies the cluster tier.
/// `batch` is the controller batch size — the most samples one
/// `evaluate_batch` call can carry, so service connections beyond it
/// could never be used. The chosen backend comes back wrapped in an
/// [`EvalBroker`]: single searches run through one broker session,
/// `nahas sweep` runs many concurrently over the same broker — and
/// with `--cache-dir`, the broker warm-starts from (and spills back
/// to) a persistent cache shared across runs and backend tiers.
/// `--broker-inflight N` caps how many concurrent session batches the
/// broker admits against the backend (clamped to the backend's
/// capacity hint; defaults to that capacity, so parallel-capable
/// tiers overlap out of the box and `--broker-inflight 1` restores
/// strictly serial one-batch-at-a-time dispatch).
/// `--dispatch-chunk N` bounds how many queued keys one backend
/// dispatch may carry (defaults to the backend's capacity hint, so a
/// long shared queue streams through in capacity-sized chunks and
/// early sessions unblock as soon as their keys complete; a very
/// large N restores the old drain-the-whole-queue behaviour).
fn evaluator_arg(
    flags: &Flags,
    space: NasSpace,
    seed: u64,
    batch: usize,
) -> Result<EvalBroker> {
    Ok(evaluator_arg_observed(flags, space, seed, batch)?.0)
}

/// [`evaluator_arg`] plus the cluster tier's [`MembershipLog`] (when
/// the backend is the cluster tier), so `nahas sweep` can carry
/// join/leave transitions in its metrics rows. Also fills the cluster
/// tier's warm-handoff source with the broker's warm cache — this has
/// to happen here, after the evaluator is boxed into the broker.
fn evaluator_arg_observed(
    flags: &Flags,
    space: NasSpace,
    seed: u64,
    batch: usize,
) -> Result<(EvalBroker, Option<MembershipLog>)> {
    let workers = workers_arg(flags)?;
    let seg = flags.bool("seg");
    let space_id = space.id;
    let kind = flags.get("evaluator").unwrap_or(if flags.get("remote").is_some() {
        "service"
    } else if flags.get("hosts").is_some() {
        "cluster"
    } else {
        "local"
    });
    if kind != "service" && flags.get("remote").is_some() {
        bail!("--remote is only used by the service tier; drop it or pass --evaluator service");
    }
    if kind != "cluster" && flags.get("hosts").is_some() {
        bail!("--hosts is only used by the cluster tier; drop it or pass --evaluator cluster");
    }
    if kind != "service" && kind != "cluster" && flags.get("wire").is_some() {
        bail!("--wire only applies to the service and cluster tiers");
    }
    if kind != "cluster" {
        for f in ["io-timeout", "membership-dir"] {
            if flags.get(f).is_some() {
                bail!("--{f} only applies to the cluster tier");
            }
        }
    }
    let mut cluster_hooks: Option<(WarmSource, MembershipLog)> = None;
    let backend: Box<dyn Evaluator + Send> = match kind {
        "local" => {
            let mut ev = SurrogateSim::new(space, seed);
            if seg {
                ev = ev.segmentation();
            }
            Box::new(ev)
        }
        "parallel" => {
            let mut ev = ParallelSim::new(space, seed, workers);
            if seg {
                ev = ev.segmentation();
            }
            Box::new(ev)
        }
        "service" => {
            let addr = flags
                .get("remote")
                .ok_or_else(|| anyhow!("--evaluator service requires --remote ADDR"))?;
            let conns = workers.min(batch.max(1));
            let mut ev =
                ServiceEvaluator::connect_wire(addr, space.id, seed, conns, wire_arg(flags)?)?;
            if seg {
                ev = ev.segmentation();
            }
            Box::new(ev)
        }
        "cluster" => {
            let raw = flags
                .get("hosts")
                .ok_or_else(|| anyhow!("--evaluator cluster requires --hosts A,B,..."))?;
            let hosts = hosts_arg(raw)?;
            // Split the worker budget over the pool, but keep at least
            // one connection per host and never more than the batch.
            let per_host = (workers / hosts.len()).clamp(1, batch.max(1));
            let wire = wire_arg(flags)?;
            // `--io-timeout SECS`: per-roundtrip socket timeout for
            // every cluster connection (whole seconds, >= 1; the API
            // below it takes any Duration for sub-second test runs).
            let mut ev = match flags.get("io-timeout") {
                Some(_) => {
                    let secs = flags.u64("io-timeout", 0)?;
                    if secs < 1 {
                        bail!("--io-timeout must be at least 1 (whole seconds)");
                    }
                    ShardedEvaluator::connect_weighted_opts(
                        &hosts,
                        space.id,
                        seed,
                        per_host,
                        wire,
                        Duration::from_secs(secs),
                    )?
                }
                None => ShardedEvaluator::connect_weighted_wire(
                    &hosts, space.id, seed, per_host, wire,
                )?,
            }
            .with_health_probes(Duration::from_millis(500));
            if seg {
                ev = ev.segmentation();
            }
            // `--membership-dir DIR`: poll DIR/membership.plan before
            // every batch, so `nahas cluster join|leave ADDR
            // --membership-dir DIR` from another terminal reshapes
            // this live pool.
            if let Some(dir) = flags.get("membership-dir") {
                ev = ev.with_membership_dir(dir);
                println!(
                    "cluster: polling {} for membership changes",
                    membership::plan_path(Path::new(dir)).display()
                );
            }
            println!("cluster: {}/{} hosts up", ev.hosts_up(), ev.hosts());
            cluster_hooks = Some((ev.warm_source(), ev.membership_log()));
            Box::new(ev)
        }
        other => bail!("unknown evaluator '{other}' (local|parallel|service|cluster)"),
    };
    let store = cache_store_arg(flags, space_id, seg, seed)?;
    let broker = broker_with_flags(flags, backend, store)?;
    // Warm-handoff source: a joining host's key range is carved out of
    // the broker's warm cache. `warm_entries` takes only the broker's
    // state lock — free while the cluster backend (which triggers
    // joins mid-dispatch) is checked out — so this cannot deadlock.
    let log = cluster_hooks.map(|(warm, log)| {
        let b = broker.clone();
        warm.set(move || b.warm_entries());
        log
    });
    Ok((broker, log))
}

/// Wrap a backend in an [`EvalBroker`], honouring the shared broker
/// flags (`--broker-inflight`, `--dispatch-chunk`) and an optional
/// persistent store. Shared by [`evaluator_arg`], the multi-task
/// scenario backend, and the oneshot oracle.
fn broker_with_flags(
    flags: &Flags,
    backend: Box<dyn Evaluator + Send>,
    store: Option<CacheStore>,
) -> Result<EvalBroker> {
    let broker = match store {
        Some(store) => EvalBroker::with_store(backend, store),
        None => EvalBroker::new(backend),
    };
    let broker = match flags.get("broker-inflight") {
        Some(_) => {
            let n = flags.usize("broker-inflight", 0)?;
            if n == 0 {
                bail!("--broker-inflight must be at least 1 (1 = serial admission)");
            }
            broker.with_inflight_limit(n)
        }
        None => broker,
    };
    Ok(match flags.get("dispatch-chunk") {
        Some(_) => {
            let n = flags.usize("dispatch-chunk", 0)?;
            if n == 0 {
                bail!("--dispatch-chunk must be at least 1 (keys per backend dispatch)");
            }
            broker.with_dispatch_chunk(n)
        }
        None => broker,
    })
}

/// Build the broker for a multi-task scenario set: a task-dispatching
/// [`MultiTaskEval`] over per-task simulator backends. Multi-task
/// joint keys carry a task prefix, so the persistent cache file (and
/// its fingerprint) are keyed by the scenario's whole task SET —
/// a multi-task cache can never warm-start a single-task run.
fn multi_task_broker(
    flags: &Flags,
    scenarios: &[Scenario],
    space: NasSpaceId,
    seed: u64,
) -> Result<EvalBroker> {
    let kind = flags.get("evaluator").unwrap_or("local");
    let workers = match kind {
        "local" => 1,
        "parallel" => workers_arg(flags)?,
        other => bail!(
            "multi-task scenarios evaluate through a task-dispatching in-process backend; \
             --evaluator {other} is not supported yet (use local|parallel)"
        ),
    };
    if flags.bool("seg") {
        bail!("--seg conflicts with multi-task scenarios (each task declares its own variant)");
    }
    let tasks = scenarios[0]
        .tasks
        .as_ref()
        .expect("multi_task_broker called without a multi-task scenario");
    let store = match flags.get("cache-dir") {
        Some(dir) => {
            let kinds = scenarios[0].tasks_key();
            let path = eval_cache_file_tasks(Path::new(dir), space, &kinds, seed);
            let store = CacheStore::open(&path, &eval_fingerprint_tasks(space, &kinds, seed))?;
            report_cache_store(&store);
            Some(store)
        }
        None => None,
    };
    let backend = Box::new(MultiTaskEval::surrogate(tasks, space, seed, workers));
    broker_with_flags(flags, backend, store)
}

fn print_eval_stats(st: &nahas::search::EvalStats) {
    // Only interesting for caching evaluators; the local tier's
    // requests == evals and the samples/s already printed say it all.
    if st.cache_hits > 0 {
        println!(
            "evaluator: {} requests -> {} evals, {} cache hits ({:.0}% hit rate)",
            st.requests,
            st.evals,
            st.cache_hits,
            st.hit_rate() * 100.0,
        );
    }
    if st.cross_session_hits > 0 {
        println!(
            "  {} cross-session hits (keys first evaluated by another search session)",
            st.cross_session_hits
        );
    }
    if st.persisted_hits > 0 {
        println!(
            "  {} persisted warm-start hits (keys loaded from --cache-dir)",
            st.persisted_hits
        );
    }
    if st.inflight_hits > 0 {
        println!(
            "  {} in-flight dedup hits (requests that waited on an evaluation already \
             running in another session)",
            st.inflight_hits
        );
    }
    for h in &st.per_host {
        println!(
            "  host {}: {} routed, {} evals, {} hits{}",
            h.host,
            h.requests,
            h.evals,
            h.cache_hits(),
            if h.down { "  [DOWN]" } else { "" }
        );
    }
    if st.hosts_down > 0 {
        println!("  {} host(s) down during this run", st.hosts_down);
    }
}

fn reward_arg(flags: &Flags) -> Result<RewardCfg> {
    let mut r = if let Some(e) = flags.get("target-mj") {
        RewardCfg::energy(e.parse().context("--target-mj")?)
    } else {
        RewardCfg::latency(flags.f64("target-ms", 0.5)?)
    };
    if flags.get("mode") == Some("soft") {
        r = r.soft();
    }
    Ok(r)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    // `cluster join|leave ADDR` carries positional operands, which the
    // `--key value` parser rejects; peel them off before flag parsing.
    if cmd == "cluster" {
        return cmd_cluster(&args[1..]);
    }
    let flags = Flags::parse(&args[1..])?;
    match cmd.as_str() {
        "simulate" => cmd_simulate(&flags),
        "search" => cmd_search(&flags),
        "sweep" => cmd_sweep(&flags),
        "scenarios" => cmd_scenarios(),
        "phase" => cmd_phase(&flags),
        "oneshot" => cmd_oneshot(&flags),
        "train-child" => cmd_train_child(&flags),
        "costmodel" => cmd_costmodel(&flags),
        "serve" => cmd_serve(&flags),
        "cluster-status" => cmd_cluster_status(&flags),
        "help" | "--help" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try 'nahas help')"),
    }
}

fn print_usage() {
    println!(
        "nahas — joint Neural Architecture and Hardware Accelerator Search\n\
         \n\
         commands:\n\
         \x20 simulate     [--random N --space s1|s2|s3|proxy --seed S --detail MODEL]\n\
         \x20 search       [--space s2 --samples 500 --target-ms 0.5 | --target-mj 1.0]\n\
         \x20              [--controller ppo|random|evolution|reinforce --fixed-hw]\n\
         \x20              [--mode hard|soft --seg --seed S --out results/search.csv]\n\
         \x20              [--evaluator local|parallel|service|cluster --workers N --batch 16]\n\
         \x20              [--remote ADDR   use a `nahas serve` simulator service]\n\
         \x20              [--hosts A,B=2,..  shard over weighted `nahas serve` hosts]\n\
         \x20              [--cache-dir DIR  persist evaluations across runs (warm start)]\n\
         \x20              [--broker-inflight N  concurrent session batches (1 = serial)]\n\
         \x20              [--dispatch-chunk N  keys per backend dispatch (streaming)]\n\
         \x20              [--wire json|binary  remote-tier wire protocol (default binary)]\n\
         \x20 sweep        [--targets 0.3,0.5,0.7 --objectives latency,energy,area]\n\
         \x20              [--drivers joint,phase --samples 500 --batch 16 --seed S]\n\
         \x20              [--scenario NAME[,NAME..]  run registered substrates instead\n\
         \x20              \x20of the grid (see `nahas scenarios`; multi-task substrates\n\
         \x20              \x20report per-task frontiers)]\n\
         \x20              [--space s2 --out results/sweep.csv]\n\
         \x20              [--evaluator local|parallel|service|cluster --workers N]\n\
         \x20              [--cache-dir DIR  warm-start repeated sweeps from disk]\n\
         \x20              [--broker-inflight N  overlap scenario batches on the backend]\n\
         \x20              [--dispatch-chunk N  keys per backend dispatch (streaming)]\n\
         \x20              [--checkpoint DIR  resumable sweep: completed scenarios\n\
         \x20              \x20survive a kill and replay bit-identically on re-run]\n\
         \x20              [--sweep-threads N  concurrent scenarios (default: all)]\n\
         \x20              [--metrics FILE --metrics-interval SECS  live JSONL rows +\n\
         \x20              \x20a stderr progress line while the sweep runs]\n\
         \x20              runs all scenarios concurrently over one shared broker\n\
         \x20 scenarios    list registered scenario substrates (for sweep --scenario)\n\
         \x20 phase        [--space s2 --samples 500 --target-ms 0.5 --seed S]\n\
         \x20              [--evaluator local|parallel|service|cluster --workers N --batch 16]\n\
         \x20              [--cache-dir DIR --broker-inflight N --dispatch-chunk N]\n\
         \x20 oneshot      [--warmup 60 --steps 200 --target-ms 0.02 --seed S]\n\
         \x20              [--cache-dir DIR  warm-start the cost oracle from disk]\n\
         \x20 train-child  [--steps 30 --seed S]\n\
         \x20 costmodel    [--data 2000 --train-steps 600 --eval 256 --space s2]\n\
         \x20 serve        [--addr 127.0.0.1:7878 --cache-dir DIR]\n\
         \x20              [--event-threads N --sim-workers N  event-loop sizing]\n\
         \x20              [--metrics FILE --metrics-interval SECS  live JSONL rows]\n\
         \x20 cluster      join|leave ADDR --membership-dir DIR [--weight W]\n\
         \x20              \x20queue an elastic membership change; a live sweep run\n\
         \x20              \x20with the same --membership-dir applies it before its\n\
         \x20              \x20next batch (joins get a warm-cache handoff first)\n\
         \x20 cluster-status [--hosts a:7878,b:7878=2 --timeout-ms 1000]\n\
         \x20              [--watch --watch-interval SECS --watch-count N\n\
         \x20              \x20re-probe on an interval, printing up/DOWN transitions]\n\
         \n\
         cluster-tier search/sweep extras:\n\
         \x20              [--io-timeout SECS  per-roundtrip socket timeout (>= 1)]\n\
         \x20              [--membership-dir DIR  poll for cluster join|leave commands]"
    );
}

fn cmd_simulate(flags: &Flags) -> Result<()> {
    let cfg = AcceleratorConfig::baseline();
    if let Some(which) = flags.get("detail") {
        return cmd_simulate_detail(which);
    }
    let mut table = Table::new(&[
        "Model", "MACs(M)", "Params(M)", "Latency(ms)", "Energy(mJ)", "Power(W)", "Util",
    ]);
    let nets: Vec<(String, nahas::model::NetworkIr)> = if flags.get("random").is_some() {
        let n = flags.usize("random", 8)?;
        let space = space_arg(flags)?;
        let mut rng = Rng::new(flags.u64("seed", 0)?);
        (0..n)
            .map(|i| {
                let d = space.random(&mut rng);
                let net = space.decode(&d);
                (format!("{}#{i}", net.name), net)
            })
            .collect()
    } else {
        baselines::all_baselines().into_iter().map(|(n, net)| (n.to_string(), net)).collect()
    };
    for (name, net) in nets {
        match simulate_network(&cfg, &net) {
            Err(e) => println!("{name}: INVALID ({e})"),
            Ok(r) => table.row(vec![
                name,
                format!("{:.0}", net.total_macs() as f64 / 1e6),
                format!("{:.2}", net.total_params() as f64 / 1e6),
                format!("{:.3}", r.latency_ms),
                format!("{:.3}", r.energy_mj),
                format!("{:.2}", r.power_w),
                format!("{:.2}", r.utilization),
            ]),
        }
    }
    table.print();
    Ok(())
}

/// Per-layer cost breakdown of one named baseline (profiling view).
fn cmd_simulate_detail(which: &str) -> Result<()> {
    let net = baselines::all_baselines()
        .into_iter()
        .find(|(n, _)| n.eq_ignore_ascii_case(which) || n.to_lowercase().contains(&which.to_lowercase()))
        .map(|(_, net)| net)
        .ok_or_else(|| anyhow!("unknown model '{which}' (see `nahas simulate` for names)"))?;
    let cfg = AcceleratorConfig::baseline();
    let mut per = Vec::new();
    let rep = nahas::accel::simulate_network_detailed(&cfg, &net, &mut per)
        .map_err(|e| anyhow!("{e}"))?;
    let mut table = Table::new(&[
        "#", "Layer", "MACs(M)", "Cycles(k)", "Compute(k)", "DMA(k)", "Util", "DRAM(KB)",
    ]);
    for (i, (li, c)) in net.layers.iter().zip(&per).enumerate() {
        table.row(vec![
            format!("{i}"),
            format!("{:?}", li.op).chars().take(44).collect(),
            format!("{:.2}", c.macs as f64 / 1e6),
            format!("{:.1}", c.cycles as f64 / 1e3),
            format!("{:.1}", c.compute_cycles as f64 / 1e3),
            format!("{:.1}", c.dma_cycles as f64 / 1e3),
            format!("{:.2}", c.utilization),
            format!("{:.1}", c.dram_read_bytes as f64 / 1e3),
        ]);
    }
    table.print();
    println!(
        "total: {:.3} ms, {:.3} mJ, util {:.2}, dram {:.2} MB",
        rep.latency_ms, rep.energy_mj, rep.utilization, rep.dram_traffic_mb
    );
    Ok(())
}

fn cmd_search(flags: &Flags) -> Result<()> {
    let space = space_arg(flags)?;
    let has = HasSpace::new();
    let (cards, layout) = JointLayout::cards(&space, &has);
    let reward = reward_arg(flags)?;
    let seed = flags.u64("seed", 0)?;
    let mut cfg = SearchCfg::new(flags.usize("samples", 500)?, reward, seed);
    cfg.batch = flags.usize("batch", cfg.batch)?.max(1);
    let fixed_hw = flags.bool("fixed-hw").then(|| has.baseline_decisions());
    let free_cards = if fixed_hw.is_some() { cards[..layout.nas_len].to_vec() } else { cards };

    let mut controller: Box<dyn Controller> = match flags.get("controller").unwrap_or("ppo") {
        "ppo" => Box::new(PpoController::new(&free_cards)),
        "random" => Box::new(RandomController::new(free_cards)),
        "evolution" => Box::new(EvolutionController::new(free_cards)),
        "reinforce" => Box::new(ReinforceController::new(&free_cards)),
        other => bail!("unknown controller '{other}'"),
    };
    let broker = evaluator_arg(flags, space, seed, cfg.batch)?;
    let mut session = broker.session();
    let out = joint_search(
        &mut session,
        controller.as_mut(),
        &layout,
        fixed_hw.as_deref(),
        None,
        &cfg,
    );
    println!(
        "search done: {} samples in {:.2}s ({:.0} samples/s), {} invalid",
        cfg.samples,
        out.elapsed_s,
        out.samples_per_s(),
        out.num_invalid
    );
    // Whole-broker view: session counters plus the backend's per-host
    // attribution when the cluster tier is behind the broker.
    print_eval_stats(&broker.stats());
    if let Some(b) = &out.best_feasible {
        println!(
            "best feasible: acc {:.2}% lat {:.3}ms energy {:.3}mJ area {:.1}mm2",
            b.result.acc * 100.0,
            b.result.latency_ms,
            b.result.energy_mj,
            b.result.area_mm2
        );
        println!("  nas = {:?}", b.nas_d);
        println!("  hw  = {:?}", b.has_d);
    } else {
        println!("no feasible sample found");
    }
    if let Some(path) = flags.get("out") {
        metrics::write_history_csv(path, &out.history)?;
        println!("history written to {path}");
    }
    Ok(())
}

fn cmd_phase(flags: &Flags) -> Result<()> {
    let space = space_arg(flags)?;
    let seed = flags.u64("seed", 0)?;
    let mut cfg = SearchCfg::new(flags.usize("samples", 500)?, reward_arg(flags)?, seed);
    cfg.batch = flags.usize("batch", cfg.batch)?.max(1);
    let broker = evaluator_arg(flags, space.clone(), seed, cfg.batch)?;
    let initial = vec![0; space.num_decisions()];
    let out = phase_search(&broker, &space, &initial, &cfg);
    println!("phase 1 selected hw: {:?}", out.selected_hw);
    match &out.nas_phase.best_feasible {
        Some(b) => println!(
            "phase 2 best feasible: acc {:.2}% lat {:.3}ms",
            b.result.acc * 100.0,
            b.result.latency_ms
        ),
        None => println!("phase 2 found no feasible sample"),
    }
    // Whole-run stats: the HAS and NAS phases share one broker, so
    // cache-hit reporting covers both (not just the NAS half), and the
    // broker view keeps per-host attribution on the cluster tier.
    print_eval_stats(&broker.stats());
    Ok(())
}

/// Parse a comma-separated list of numbers.
fn csv_f64(raw: &str, flag: &str) -> Result<Vec<f64>> {
    let mut out = Vec::new();
    for tok in raw.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        out.push(tok.parse().with_context(|| format!("--{flag}: bad number '{tok}'"))?);
    }
    if out.is_empty() {
        bail!("--{flag} needs at least one value");
    }
    Ok(out)
}

/// Drop repeated values, keeping first occurrences — a duplicated
/// target/objective/driver would silently run the same scenario twice.
fn dedup_keep_order<T: PartialEq + Copy>(v: &mut Vec<T>) {
    let mut seen: Vec<T> = Vec::new();
    v.retain(|x| {
        if seen.contains(x) {
            false
        } else {
            seen.push(*x);
            true
        }
    });
}

/// `nahas sweep` — the concurrent multi-scenario orchestrator: a grid
/// of scenarios (targets x objectives x drivers) runs as concurrent
/// search sessions over ONE shared evaluation broker, so the whole
/// sweep shares the backend's worker/service/cluster capacity and one
/// cross-search memo cache; the per-scenario winners merge into a
/// union Pareto frontier per objective.
fn cmd_sweep(flags: &Flags) -> Result<()> {
    let space = space_arg(flags)?;
    let space_id = space.id;
    let seed = flags.u64("seed", 0)?;
    let samples = flags.usize("samples", 500)?;
    let batch = flags.usize("batch", 16)?.max(1);
    let scenario_names: Vec<String> = flags
        .get("scenario")
        .map(|raw| {
            raw.split(',')
                .map(str::trim)
                .filter(|t| !t.is_empty())
                .map(String::from)
                .collect()
        })
        .unwrap_or_default();

    let scenarios = if scenario_names.is_empty() {
        // Classic grid path: targets x objectives x drivers.
        let targets = csv_f64(flags.get("targets").unwrap_or("0.3,0.5,0.7"), "targets")?;
        let mut objectives = Vec::new();
        let objective_toks = flags.get("objectives").unwrap_or("latency");
        for tok in objective_toks.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            objectives.push(match tok {
                "latency" | "lat" => CostObjective::Latency,
                "energy" => CostObjective::Energy,
                "area" => CostObjective::Area,
                other => bail!("unknown objective '{other}' (latency|energy|area)"),
            });
        }
        let mut drivers = Vec::new();
        let driver_toks = flags.get("drivers").unwrap_or("joint");
        for tok in driver_toks.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            drivers.push(match tok {
                "joint" => SweepDriver::Joint,
                "phase" => SweepDriver::Phase,
                other => bail!("unknown driver '{other}' (joint|phase)"),
            });
        }
        if objectives.is_empty() {
            bail!("--objectives needs at least one of latency|energy|area");
        }
        if drivers.is_empty() {
            bail!("--drivers needs at least one of joint|phase");
        }
        let mut targets = targets;
        dedup_keep_order(&mut targets);
        dedup_keep_order(&mut objectives);
        dedup_keep_order(&mut drivers);
        scenario_grid(&targets, &objectives, &drivers, space_id, samples, batch, seed)
    } else {
        // Registry path: compile named substrates from `nahas scenarios`.
        if flags.get("objectives").is_some() || flags.get("drivers").is_some() {
            bail!(
                "--scenario compiles registered substrates with their own objectives and \
                 drivers; drop --objectives/--drivers (combine substrates with a comma instead)"
            );
        }
        let targets = match flags.get("targets") {
            Some(raw) => {
                let mut t = csv_f64(raw, "targets")?;
                dedup_keep_order(&mut t);
                t
            }
            // Empty = each substrate supplies its own default targets.
            None => Vec::new(),
        };
        let registry = builtin_registry();
        let params = SubstrateParams::new(space_id, samples, batch, seed).targets(targets);
        compile_substrates(&registry, &scenario_names, &params)?
    };
    if scenarios.is_empty() {
        bail!("no scenarios to run");
    }
    let multi_task = !scenarios[0].tasks_key().is_empty();
    let (broker, membership_log) = if multi_task {
        (multi_task_broker(flags, &scenarios, space_id, seed)?, None)
    } else {
        evaluator_arg_observed(flags, space, seed, batch)?
    };
    println!(
        "sweep: {} scenarios x {} samples, concurrent over one shared evaluation broker",
        scenarios.len(),
        samples
    );
    // `--checkpoint DIR`: resumable sweeps. Completed scenarios are
    // appended to DIR/sweep.ckpt as they finish; a re-run of the same
    // sweep (same eval fingerprint + per-scenario config digest)
    // replays them bit-identically instead of re-evaluating.
    let mut ckpt = match flags.get("checkpoint") {
        Some(dir) => {
            let kinds = scenarios[0].tasks_key();
            let fp = if kinds.is_empty() {
                let task =
                    if flags.bool("seg") { Task::Segmentation } else { Task::Classification };
                eval_fingerprint(space_id, task, seed)
            } else {
                eval_fingerprint_tasks(space_id, &kinds, seed)
            };
            let c = SweepCheckpoint::open(Path::new(dir), &fp)?;
            match c.discarded() {
                Some(why) => println!(
                    "sweep checkpoint {}: stale contents discarded ({why}); cold start",
                    c.path().display()
                ),
                None => println!(
                    "sweep checkpoint {}: {} completed scenarios loaded",
                    c.path().display(),
                    c.loaded_len()
                ),
            }
            Some(c)
        }
        None => None,
    };
    let threads = flags.usize("sweep-threads", scenarios.len())?.max(1);
    // `--metrics FILE`: live JSONL side channel (one row per
    // `--metrics-interval` seconds) plus a progress line on stderr.
    // Observation is read-only — the broker snapshot never waits out a
    // dispatch and the progress gauge is relaxed atomics — so search
    // results are bit-identical with or without it
    // (`tests/metrics_stream.rs`).
    let progress = std::sync::Arc::new(SweepProgress::new());
    let streamer = match flags.get("metrics") {
        Some(path) => {
            let interval = flags.f64("metrics-interval", 5.0)?;
            let mut sink = metrics::MetricsSink::create(path)?;
            // Cluster backend: membership transitions (join/leave +
            // handoff counts) ride along in the metrics rows.
            if let Some(log) = &membership_log {
                sink = sink.with_membership(log.clone());
            }
            println!("live metrics -> {path} (one row every {interval}s)");
            Some(metrics::MetricsStreamer::spawn(
                broker.clone(),
                sink,
                std::time::Duration::from_secs_f64(interval.max(0.05)),
                Some(progress.clone()),
            ))
        }
        None => None,
    };
    let out = run_sweep_observed(&broker, &scenarios, ckpt.as_mut(), threads, Some(&progress));
    if let Some(s) = streamer {
        // Emits one final row + the final stderr summary (the metrics
        // CI smoke greps both), and surfaces any stream write error.
        let (path, rows) = s.stop()?;
        println!("metrics stream: {rows} rows -> {}", path.display());
    }
    if let Some(c) = &ckpt {
        // Resumed scenarios replay from the checkpoint file and never
        // reach the broker, so their re-evaluation count is zero by
        // construction (the resume CI smoke greps this line).
        println!(
            "sweep checkpoint: resumed {} scenarios, 0 re-evaluations ({} recorded this run)",
            c.resumed(),
            c.recorded()
        );
    }

    let mut table = Table::new(&[
        "Scenario", "Best acc(%)", "Latency(ms)", "Energy(mJ)", "Feasible", "Evals", "Hits",
    ]);
    for o in &out.outcomes {
        let b = o.search.best_feasible.as_ref();
        let cell = |v: Option<String>| v.unwrap_or_else(|| "-".to_string());
        table.row(vec![
            o.scenario.name.clone(),
            cell(b.map(|s| format!("{:.2}", s.result.acc * 100.0))),
            cell(b.map(|s| format!("{:.3}", s.result.latency_ms))),
            cell(b.map(|s| format!("{:.3}", s.result.energy_mj))),
            if b.is_some() { "yes" } else { "NO" }.to_string(),
            format!("{}", o.eval_stats.evals),
            format!("{}", o.eval_stats.cache_hits),
        ]);
    }
    table.print();

    let m = &out.eval_stats;
    println!(
        "sweep done in {:.2}s: {} requests -> {} evals, {} cache hits \
         ({} cross-scenario)",
        out.elapsed_s, m.requests, m.evals, m.cache_hits, m.cross_session_hits
    );
    // Warm-start accounting: a fully-warm re-sweep from a populated
    // --cache-dir reports zero backend evals (the CI smoke greps this).
    println!("backend evals this run: {}", broker.backend_stats().requests);
    print_eval_stats(&broker.stats());
    // Admission-control accounting: how much the scenarios actually
    // overlapped on the backend (the CI smoke greps this line too).
    let ov = broker.overlap_stats();
    let (limit, cap) = (ov.inflight_limit, ov.capacity);
    println!(
        "broker admission: limit {limit} (backend capacity {cap}), peak {} overlapping \
         batches, {} dispatches ({} coalesced)",
        ov.peak_admitted, ov.dispatches, ov.coalesced_dispatches
    );
    // Streaming-dispatch accounting: how often a dispatch had to leave
    // keys queued for a later chunk, and the deepest the shared queue
    // ever got (the streaming CI smoke greps this line).
    println!(
        "broker dispatch: chunk {}, {} chunked dispatches, peak queue depth {}",
        ov.chunk_limit, ov.chunked_dispatches, ov.peak_queue_depth
    );

    // Multi-task scenarios additionally report one frontier per task
    // (acc vs. the scenario objective, restricted to that task's
    // evaluations) — the folded union rows below mix tasks.
    for (key, front) in &out.task_frontiers {
        match front.last() {
            Some(p) => println!(
                "per-task frontier {key}: {} points (top acc {:.2}% @ cost {:.4})",
                front.len(),
                p.acc,
                p.cost
            ),
            None => println!("per-task frontier {key}: 0 points"),
        }
    }
    // N-dimensional frontiers (scenarios with `frontier_objectives`,
    // e.g. the tri-objective substrate) — reporting only, never part
    // of the search trajectory.
    for (axes, front) in &out.union_nd {
        let label: Vec<String> =
            axes.iter().map(|o| format!("{o:?}").to_lowercase()).collect();
        println!(
            "N-dim union frontier ({}): {} non-dominated points",
            label.join("+"),
            front.len()
        );
    }

    let mut rows = Vec::new();
    for (objective, front) in &out.union {
        let unit = objective.unit();
        println!("\nunion Pareto frontier ({unit} objective, {} points):", front.len());
        let cost_col = format!("Cost({unit})");
        let mut ftable = Table::new(&["Acc(%)", cost_col.as_str(), "Scenario"]);
        for p in front {
            ftable.row(vec![format!("{:.2}", p.acc), format!("{:.4}", p.cost), p.tag.clone()]);
            rows.push(vec![
                unit.to_string(),
                format!("{:.3}", p.acc),
                format!("{:.4}", p.cost),
                p.tag.clone(),
            ]);
        }
        ftable.print();
    }
    if let Some(path) = flags.get("out") {
        metrics::write_csv(path, &["objective", "acc", "cost", "scenario"], &rows)?;
        println!("union frontier written to {path}");
    }
    Ok(())
}

/// `nahas scenarios` — list the registered scenario substrates that
/// `nahas sweep --scenario NAME` can compile and run.
fn cmd_scenarios() -> Result<()> {
    let registry = builtin_registry();
    println!("registered scenario substrates ({}):", registry.len());
    let mut table = Table::new(&["Name", "Tasks", "Objectives", "Summary"]);
    for s in &registry {
        let tasks: Vec<String> =
            s.tasks().iter().map(|t| format!("{t:?}").to_lowercase()).collect();
        let objectives: Vec<String> =
            s.objectives().iter().map(|o| format!("{o:?}").to_lowercase()).collect();
        table.row(vec![
            s.name().to_string(),
            tasks.join("+"),
            objectives.join("+"),
            s.summary().to_string(),
        ]);
    }
    table.print();
    println!("run one with: nahas sweep --scenario NAME[,NAME..] [--targets 0.5,..]");
    Ok(())
}

fn cmd_oneshot(flags: &Flags) -> Result<()> {
    let rt = Runtime::load(Runtime::default_dir())?;
    let seed = flags.u64("seed", 0)?;
    let mut trainer = ProxyTrainer::new(rt, seed)?;
    let cfg = OneshotCfg {
        warmup_steps: flags.usize("warmup", 60)?,
        search_steps: flags.usize("steps", 200)?,
        t_latency_ms: flags.f64("target-ms", 0.02)?,
        seed,
        ..Default::default()
    };
    // The cost oracle is a broker session over the simulator backend:
    // same latencies/areas as querying the simulator directly, but with
    // memoized repeats and (with --cache-dir) persistent warm starts.
    let store = cache_store_arg(flags, NasSpaceId::Proxy, false, seed)?;
    let backend: Box<dyn Evaluator + Send> =
        Box::new(SurrogateSim::new(NasSpace::new(NasSpaceId::Proxy), seed));
    let broker = broker_with_flags(flags, backend, store)?;
    let mut oracle = BrokerOracle::new(&broker);
    let t0 = std::time::Instant::now();
    let out = oneshot_search(&mut trainer, &mut oracle, &cfg)?;
    println!(
        "oneshot done in {:.1}s: final acc {:.3}, lat {:.4}ms (target {}), area {:.1}mm2",
        t0.elapsed().as_secs_f64(),
        out.final_acc,
        out.final_latency_ms,
        cfg.t_latency_ms,
        out.final_area_mm2
    );
    println!("  nas = {:?}", out.best_nas);
    println!("  hw  = {:?}", out.best_has);
    println!(
        "  oracle: {} queries -> {} evals ({} memo hits)",
        out.oracle_requests,
        out.oracle_evals,
        out.oracle_requests - out.oracle_evals
    );
    print_eval_stats(&broker.stats());
    Ok(())
}

fn cmd_train_child(flags: &Flags) -> Result<()> {
    let rt = Runtime::load(Runtime::default_dir())?;
    let mut trainer = ProxyTrainer::new(rt, flags.u64("seed", 0)?)?;
    trainer.steps = flags.usize("steps", 30)?;
    let space = trainer.space().clone();
    let mut rng = Rng::new(flags.u64("seed", 0)?);
    let d = space.random(&mut rng);
    let t0 = std::time::Instant::now();
    let acc = trainer.train_child(&d, 1)?;
    println!(
        "child {:?}: acc {:.3} after {} steps in {:.1}s",
        d,
        acc,
        trainer.steps,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_costmodel(flags: &Flags) -> Result<()> {
    let space = space_arg(flags)?;
    let mut rng = Rng::new(flags.u64("seed", 0)?);
    let n = flags.usize("data", 2000)?;
    let t0 = std::time::Instant::now();
    let (data, norm) = costmodel::generate_dataset(&space, n, &mut rng);
    println!("generated {} labelled samples in {:.2}s", data.len(), t0.elapsed().as_secs_f64());

    let mut rt = Runtime::load(Runtime::default_dir())?;
    let mut cm = CostModel::init(&mut rt, norm, 0)?;
    let holdout = flags.usize("eval", 256)?.min(data.len() / 4);
    let (test, train) = data.split_at(holdout);
    let steps = flags.usize("train-steps", 600)?;
    let losses = cm.train(&mut rt, train, steps, &mut rng)?;
    println!(
        "trained {} steps: loss {:.4} -> {:.4}",
        steps,
        losses.first().unwrap_or(&0.0),
        losses.last().unwrap_or(&0.0)
    );
    let feats: Vec<Vec<f32>> = test.iter().map(|s| s.features.clone()).collect();
    let preds = cm.predict(&mut rt, &feats)?;
    let refs: Vec<&costmodel::CostSample> = test.iter().collect();
    let (rel, corr) = costmodel::host::accuracy_metrics(&preds, &refs);
    println!("holdout: mean relative latency error {:.1}%, corr {:.3}", rel * 100.0, corr);
    Ok(())
}

fn cmd_serve(flags: &Flags) -> Result<()> {
    let addr = flags.get("addr").unwrap_or("127.0.0.1:7878");
    let cache = match flags.get("cache-dir") {
        Some(dir) => {
            let path = Path::new(dir).join("serve.cache");
            let store: CacheStore<String> = CacheStore::open(&path, &serve_fingerprint())?;
            report_cache_store(&store);
            ServeCache::with_store(store)
        }
        None => ServeCache::default(),
    };
    let defaults = ServerOpts::default();
    let opts = ServerOpts {
        event_threads: flags.usize("event-threads", defaults.event_threads)?.max(1),
        sim_workers: flags.usize("sim-workers", defaults.sim_workers)?.max(1),
    };
    let server = Server::spawn_with_opts(addr, cache, opts)?;
    println!(
        "simulator service on {} ({} event threads, {} sim workers); Ctrl-C to stop",
        server.addr, opts.event_threads, opts.sim_workers
    );
    // `--metrics FILE`: one JSONL row per `--metrics-interval` seconds
    // from the server's own counters — same row schema as the sweep
    // stream, with `requests` the simulate requests (hits + evals) so
    // `cache_hits` is exactly the serve cache's hit counter; the
    // dispatch gauges stay zero (there is no broker here).
    if let Some(path) = flags.get("metrics") {
        let interval = flags.f64("metrics-interval", 5.0)?.max(0.05);
        let mut sink = metrics::MetricsSink::create(path)?;
        println!("live metrics -> {path} (one row every {interval}s)");
        let t0 = std::time::Instant::now();
        loop {
            std::thread::sleep(std::time::Duration::from_secs_f64(interval));
            let relaxed = std::sync::atomic::Ordering::Relaxed;
            let hits = server.cache.hits.load(relaxed) as usize;
            let evals = server.cache.sim_evals.load(relaxed) as usize;
            let snap =
                BrokerSnapshot { requests: hits + evals, evals, ..Default::default() };
            let row = sink.emit(t0.elapsed().as_secs_f64(), &snap, None)?;
            eprintln!("{}", row.progress_line());
        }
    }
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// `nahas cluster join|leave ADDR --membership-dir DIR [--weight W]` —
/// the elastic-membership admin commands. They do not touch the pool
/// directly: they append one command to `DIR/membership.plan`, and any
/// live sweep/search running its cluster tier with the same
/// `--membership-dir` applies it before its next batch (joins receive
/// a warm-cache handoff of their key range first).
fn cmd_cluster(args: &[String]) -> Result<()> {
    const USAGE: &str = "usage: nahas cluster join|leave ADDR --membership-dir DIR [--weight W]";
    let Some((action, rest)) = args.split_first() else {
        bail!("{USAGE}");
    };
    let (addr, rest) = match rest.split_first() {
        Some((a, r)) if !a.starts_with("--") => (a.clone(), r),
        _ => bail!("cluster {action} needs a host ADDR:PORT\n{USAGE}"),
    };
    let flags = Flags::parse(rest)?;
    let dir = flags.get("membership-dir").ok_or_else(|| {
        anyhow!(
            "cluster {action} requires --membership-dir DIR \
             (the directory the running sweep polls)"
        )
    })?;
    let cmd = match action.as_str() {
        "join" => {
            let weight = flags.f64("weight", 1.0)?;
            if !weight.is_finite() || weight <= 0.0 {
                bail!("--weight must be a positive number");
            }
            MembershipCmd::Join { addr, weight }
        }
        "leave" => {
            if flags.get("weight").is_some() {
                bail!("--weight only applies to cluster join");
            }
            MembershipCmd::Leave { addr }
        }
        other => bail!("unknown cluster action '{other}' (join|leave)\n{USAGE}"),
    };
    membership::append_cmd(Path::new(dir), &cmd)?;
    println!(
        "cluster {action}: queued '{}' in {} (applies before the next batch of the \
         sweep polling that directory)",
        cmd.to_line(),
        membership::plan_path(Path::new(dir)).display()
    );
    Ok(())
}

/// One cluster-status probe round: print the status table and return
/// (hosts up, per-host up flags) — the flags feed `--watch`'s
/// transition diff.
fn print_cluster_table(hosts: &[(String, f64)], timeout: Duration) -> (usize, Vec<bool>) {
    let mut table = Table::new(&[
        "Host", "Weight", "Status", "Wire", "RTT(ms)", "Served", "SimHits", "Cache",
        "Installed", "Detail",
    ]);
    let mut up = 0;
    let mut up_flags = Vec::with_capacity(hosts.len());
    for (host, weight) in hosts {
        let p = probe_host(host, timeout);
        up += p.up as usize;
        up_flags.push(p.up);
        // Negotiated wire protocol: "bin-v1" when the host acks the
        // binary hello, "json" when it predates the frame protocol.
        let wire = if p.up { probe_wire(host, timeout).unwrap_or("-") } else { "-" };
        // Hit counts, resident size and handoff-installed entries of
        // the server-side result cache, when the host answers the
        // stats protocol.
        let stats = if p.up { query_host_stats(host, timeout) } else { None };
        let (served, hits, cache, installed) = stats
            .map(|s| {
                (
                    format!("{}", s.requests),
                    format!("{}", s.cache_hits),
                    format!("{}", s.cache_size),
                    format!("{}", s.installed),
                )
            })
            .unwrap_or_else(|| {
                ("-".to_string(), "-".to_string(), "-".to_string(), "-".to_string())
            });
        table.row(vec![
            p.addr,
            format!("{weight}"),
            if p.up { "up" } else { "DOWN" }.to_string(),
            wire.to_string(),
            format!("{:.2}", p.rtt_ms),
            served,
            hits,
            cache,
            installed,
            p.detail,
        ]);
    }
    table.print();
    (up, up_flags)
}

/// Probe every `--hosts` entry with one protocol roundtrip and print
/// the pool's health plus each host's server-side cache counters (the
/// operator view of the cluster tier). With `--watch`, re-probe every
/// `--watch-interval` seconds (default 2) and print a membership
/// transition line whenever a host changes state; `--watch-count N`
/// bounds the rounds (0 = until interrupted).
fn cmd_cluster_status(flags: &Flags) -> Result<()> {
    let raw = flags
        .get("hosts")
        .ok_or_else(|| anyhow!("cluster-status requires --hosts A,B,..."))?;
    let hosts = hosts_arg(raw)?;
    let timeout = Duration::from_millis(flags.u64("timeout-ms", 1000)?);
    if !flags.bool("watch") {
        for f in ["watch-interval", "watch-count"] {
            if flags.get(f).is_some() {
                bail!("--{f} only applies with --watch");
            }
        }
        let (up, _) = print_cluster_table(&hosts, timeout);
        println!("{up}/{} hosts up", hosts.len());
        if up == 0 {
            bail!("no cluster host reachable");
        }
        return Ok(());
    }
    let interval = flags.f64("watch-interval", 2.0)?.max(0.1);
    let rounds = flags.usize("watch-count", 0)?;
    let mut prev: Option<Vec<bool>> = None;
    let mut round = 0usize;
    loop {
        round += 1;
        let (up, now) = print_cluster_table(&hosts, timeout);
        if let Some(prev) = &prev {
            for (i, (was, is)) in prev.iter().zip(&now).enumerate() {
                if was != is {
                    println!(
                        "cluster membership: host {} {}",
                        hosts[i].0,
                        if *is { "DOWN -> up" } else { "up -> DOWN" }
                    );
                }
            }
        }
        println!("[watch {round}] {up}/{} hosts up", hosts.len());
        prev = Some(now);
        if rounds > 0 && round >= rounds {
            return Ok(());
        }
        std::thread::sleep(Duration::from_secs_f64(interval));
    }
}
