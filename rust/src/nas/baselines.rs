//! Fixed reference models of Table 3 / Fig. 1 / Fig. 8.
//!
//! Each baseline is expressed in the same layer IR and costed by the same
//! simulator as the searched models, exactly as the paper runs every
//! comparator through its performance simulator. Architectures follow the
//! published tables of their papers (MobileNetV2, EfficientNet compound
//! scaling, MnasNet-B1, ProxylessNAS-Mobile, MobileNetV3-Large); the
//! "wo SE/Swish" variants strip squeeze-excite and swish exactly as the
//! paper's Table 3 does. Manual-EdgeTPU-S/M are the paper's hand-crafted
//! models on the evolved space: fused-IBN in the early stages, IBN later.

use crate::model::{Layer, NetworkIr};

fn round8(x: f64) -> usize {
    (((x / 8.0).round() as usize) * 8).max(8)
}

/// MobileNetV2 at a width multiplier (1.0 or the paper's 1.4).
pub fn mobilenet_v2(width: f64) -> NetworkIr {
    let w = |c: usize| round8(c as f64 * width);
    let mut net = NetworkIr::new("mobilenetv2", 224, 224, 3);
    net.push(Layer::Conv2d { kh: 3, kw: 3, cin: 3, cout: w(32), stride: 2, groups: 1 });
    let spec: [(usize, usize, usize, usize); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    for (t, c, n, s) in spec {
        for i in 0..n {
            net.push_ibn(3, t, w(c), if i == 0 { s } else { 1 });
        }
    }
    let c = net.cur_c();
    net.push(Layer::Conv2d { kh: 1, kw: 1, cin: c, cout: w(1280), stride: 1, groups: 1 });
    net.push(Layer::GlobalPool { c: w(1280) });
    net.push(Layer::Dense { cin: w(1280), cout: 1000 });
    net
}

/// EfficientNet-B{n} via compound scaling; `with_se_swish` adds the SE +
/// Swish ops the paper strips for its "wo SE/Swish" rows.
pub fn efficientnet(n: usize, with_se_swish: bool) -> NetworkIr {
    // (width, depth, resolution) for B0..B3.
    let (wm, dm, res) = match n {
        0 => (1.0, 1.0, 224),
        1 => (1.0, 1.1, 240),
        2 => (1.1, 1.2, 260),
        3 => (1.2, 1.4, 300),
        _ => panic!("efficientnet B{n} not modelled"),
    };
    let w = |c: usize| round8(c as f64 * wm);
    let d = |reps: usize| ((reps as f64 * dm).ceil() as usize).max(1);
    let mut net = NetworkIr::new("efficientnet", res, res, 3);
    net.push(Layer::Conv2d { kh: 3, kw: 3, cin: 3, cout: w(32), stride: 2, groups: 1 });
    let spec: [(usize, usize, usize, usize, usize); 7] = [
        // (expand, cout, reps, stride, kernel)
        (1, 16, 1, 1, 3),
        (6, 24, 2, 2, 3),
        (6, 40, 2, 2, 5),
        (6, 80, 3, 2, 3),
        (6, 112, 3, 1, 5),
        (6, 192, 4, 2, 5),
        (6, 320, 1, 1, 3),
    ];
    for (t, c, reps, s, k) in spec {
        for i in 0..d(reps) {
            let cin = net.cur_c();
            net.push_ibn(k, t, w(c), if i == 0 { s } else { 1 });
            if with_se_swish {
                let cexp = cin * t;
                net.push(Layer::SePool { c: w(c), reduced: (cexp / 24).max(8) });
                net.push(Layer::Swish { c: w(c) });
            }
        }
    }
    let c = net.cur_c();
    net.push(Layer::Conv2d { kh: 1, kw: 1, cin: c, cout: w(1280), stride: 1, groups: 1 });
    net.push(Layer::GlobalPool { c: w(1280) });
    net.push(Layer::Dense { cin: w(1280), cout: 1000 });
    net
}

/// MnasNet-B1 (Tan et al. 2019, Table 1 of that paper).
pub fn mnasnet_b1() -> NetworkIr {
    let mut net = NetworkIr::new("mnasnet-b1", 224, 224, 3);
    net.push(Layer::Conv2d { kh: 3, kw: 3, cin: 3, cout: 32, stride: 2, groups: 1 });
    // SepConv: dw3x3 + 1x1 (expansion 1).
    net.push_ibn(3, 1, 16, 1);
    let spec: [(usize, usize, usize, usize, usize); 6] = [
        (3, 24, 3, 2, 3),
        (3, 40, 3, 2, 5),
        (6, 80, 3, 2, 5),
        (6, 96, 2, 1, 3),
        (6, 192, 4, 2, 5),
        (6, 320, 1, 1, 3),
    ];
    for (t, c, n, s, k) in spec {
        for i in 0..n {
            net.push_ibn(k, t, c, if i == 0 { s } else { 1 });
        }
    }
    let c = net.cur_c();
    net.push(Layer::Conv2d { kh: 1, kw: 1, cin: c, cout: 1280, stride: 1, groups: 1 });
    net.push(Layer::GlobalPool { c: 1280 });
    net.push(Layer::Dense { cin: 1280, cout: 1000 });
    net
}

/// MnasNet-D1-like: a deeper/wider latency-relaxed variant (the paper's
/// medium-regime MnasNet row).
pub fn mnasnet_d1() -> NetworkIr {
    let mut net = NetworkIr::new("mnasnet-d1", 224, 224, 3);
    net.push(Layer::Conv2d { kh: 3, kw: 3, cin: 3, cout: 32, stride: 2, groups: 1 });
    net.push_ibn(3, 1, 16, 1);
    let spec: [(usize, usize, usize, usize, usize); 6] = [
        (3, 24, 3, 2, 3),
        (3, 48, 3, 2, 5),
        (6, 88, 4, 2, 5),
        (6, 112, 3, 1, 3),
        (6, 224, 4, 2, 5),
        (6, 352, 1, 1, 3),
    ];
    for (t, c, n, s, k) in spec {
        for i in 0..n {
            net.push_ibn(k, t, c, if i == 0 { s } else { 1 });
        }
    }
    let c = net.cur_c();
    net.push(Layer::Conv2d { kh: 1, kw: 1, cin: c, cout: 1536, stride: 1, groups: 1 });
    net.push(Layer::GlobalPool { c: 1536 });
    net.push(Layer::Dense { cin: 1536, cout: 1000 });
    net
}

/// ProxylessNAS-Mobile (Cai et al. 2019): mixed kernel/expansion IBNs.
pub fn proxyless_mobile() -> NetworkIr {
    let mut net = NetworkIr::new("proxylessnas", 224, 224, 3);
    net.push(Layer::Conv2d { kh: 3, kw: 3, cin: 3, cout: 32, stride: 2, groups: 1 });
    net.push_ibn(3, 1, 16, 1);
    let blocks: [(usize, usize, usize, usize); 20] = [
        (5, 3, 24, 2),
        (3, 3, 24, 1),
        (7, 3, 40, 2),
        (3, 3, 40, 1),
        (5, 6, 40, 1),
        (7, 6, 80, 2),
        (5, 3, 80, 1),
        (5, 3, 80, 1),
        (5, 3, 80, 1),
        (5, 6, 96, 1),
        (5, 3, 96, 1),
        (5, 3, 96, 1),
        (5, 3, 96, 1),
        (7, 6, 192, 2),
        (7, 6, 192, 1),
        (7, 3, 192, 1),
        (7, 3, 192, 1),
        (7, 6, 320, 1),
        (5, 6, 320, 1),
        (3, 6, 320, 1),
    ];
    for (k, t, c, s) in blocks {
        net.push_ibn(k, t, c, s);
    }
    let c = net.cur_c();
    net.push(Layer::Conv2d { kh: 1, kw: 1, cin: c, cout: 1280, stride: 1, groups: 1 });
    net.push(Layer::GlobalPool { c: 1280 });
    net.push(Layer::Dense { cin: 1280, cout: 1000 });
    net
}

/// MobileNetV3-Large *with* SE + Swish (the Table 3 row showing how
/// badly SE/Swish map onto the edge array).
pub fn mobilenet_v3_se() -> NetworkIr {
    let mut net = NetworkIr::new("mobilenetv3-se", 224, 224, 3);
    net.push(Layer::Conv2d { kh: 3, kw: 3, cin: 3, cout: 16, stride: 2, groups: 1 });
    // (k, exp_ch/cin ratio approximated to nearest int, c, s, use_se)
    let blocks: [(usize, usize, usize, usize, bool); 15] = [
        (3, 1, 16, 1, false),
        (3, 4, 24, 2, false),
        (3, 3, 24, 1, false),
        (5, 3, 40, 2, true),
        (5, 3, 40, 1, true),
        (5, 3, 40, 1, true),
        (3, 6, 80, 2, false),
        (3, 3, 80, 1, false),
        (3, 3, 80, 1, false),
        (3, 3, 80, 1, false),
        (3, 6, 112, 1, true),
        (3, 6, 112, 1, true),
        (5, 6, 160, 2, true),
        (5, 6, 160, 1, true),
        (5, 6, 160, 1, true),
    ];
    for (k, t, c, s, se) in blocks {
        let cin = net.cur_c();
        net.push_ibn(k, t, c, s);
        if se {
            net.push(Layer::SePool { c, reduced: (cin * t / 4).max(8) });
        }
        net.push(Layer::Swish { c });
    }
    let c = net.cur_c();
    net.push(Layer::Conv2d { kh: 1, kw: 1, cin: c, cout: 1280, stride: 1, groups: 1 });
    net.push(Layer::Swish { c: 1280 });
    net.push(Layer::GlobalPool { c: 1280 });
    net.push(Layer::Dense { cin: 1280, cout: 1000 });
    net
}

/// Manual-EdgeTPU (paper §3.2.2 / Fig. 1): hand-crafted on the evolved
/// space — a fixed run of fused-IBN in the early, small-channel stages,
/// conventional IBN afterwards. `medium` widens + deepens.
pub fn manual_edgetpu(medium: bool) -> NetworkIr {
    let name = if medium { "manual-edgetpu-m" } else { "manual-edgetpu-s" };
    let mut net = NetworkIr::new(name, 224, 224, 3);
    let wmul = if medium { 1.25 } else { 1.0 };
    let w = |c: usize| round8(c as f64 * wmul);
    net.push(Layer::Conv2d { kh: 3, kw: 3, cin: 3, cout: w(32), stride: 2, groups: 1 });
    // Early stages: fused-IBN (full convs are cheap while channels are
    // small and utilization is the bottleneck).
    let fused: [(usize, usize, usize, usize); 5] = [
        (3, 4, 16, 1),
        (3, 8, 32, 2),
        (3, 4, 32, 1),
        (5, 8, 48, 2),
        (3, 4, 48, 1),
    ];
    for (k, t, c, s) in fused {
        net.push_fused_ibn(k, t, w(c), s, 1);
    }
    // Late stages: IBN (full convs over wide channels would explode).
    let ibn: [(usize, usize, usize, usize); 8] = [
        (3, 6, 96, 2),
        (3, 6, 96, 1),
        (3, 6, 96, 1),
        (5, 6, 160, 1),
        (5, 6, 160, 1),
        (3, 6, 192, 2),
        (3, 6, 192, 1),
        (3, 6, 320, 1),
    ];
    let extra = if medium { 3 } else { 0 };
    for (i, (k, t, c, s)) in ibn.iter().enumerate() {
        net.push_ibn(*k, *t, w(*c), *s);
        if medium && i == 4 {
            for _ in 0..extra {
                net.push_ibn(3, 6, w(*c), 1);
            }
        }
    }
    let c = net.cur_c();
    net.push(Layer::Conv2d { kh: 1, kw: 1, cin: c, cout: w(1280), stride: 1, groups: 1 });
    net.push(Layer::GlobalPool { c: w(1280) });
    net.push(Layer::Dense { cin: w(1280), cout: 1000 });
    net
}

/// All Table-3 / Fig-1 / Fig-8 baselines with their display names.
pub fn all_baselines() -> Vec<(&'static str, NetworkIr)> {
    vec![
        ("MobileNetV2", mobilenet_v2(1.0)),
        ("MobileNetV2-1.4", mobilenet_v2(1.4)),
        ("EfficientNet-B0 wo SE/Swish", efficientnet(0, false)),
        ("EfficientNet-B1 wo SE/Swish", efficientnet(1, false)),
        ("EfficientNet-B3 wo SE/Swish", efficientnet(3, false)),
        ("MnasNet-B1", mnasnet_b1()),
        ("MnasNet-D1", mnasnet_d1()),
        ("ProxylessNAS", proxyless_mobile()),
        ("MobilenetV3 w SE", mobilenet_v3_se()),
        ("Manual-EdgeTPU-S", manual_edgetpu(false)),
        ("Manual-EdgeTPU-M", manual_edgetpu(true)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mobilenet_v2_macs_match_published() {
        // Published: ~300M MACs, ~3.4M params at width 1.0.
        let net = mobilenet_v2(1.0);
        let m = net.total_macs() as f64;
        let p = net.total_params() as f64;
        assert!((250e6..360e6).contains(&m), "macs {m}");
        assert!((3.0e6..4.5e6).contains(&p), "params {p}");
    }

    #[test]
    fn efficientnet_b0_macs_match_published() {
        // Published: ~390M MACs (with SE; ours counts SE separately).
        let net = efficientnet(0, false);
        let m = net.total_macs() as f64;
        assert!((300e6..480e6).contains(&m), "macs {m}");
    }

    #[test]
    fn compound_scaling_monotone() {
        let m0 = efficientnet(0, false).total_macs();
        let m1 = efficientnet(1, false).total_macs();
        let m3 = efficientnet(3, false).total_macs();
        assert!(m0 < m1 && m1 < m3);
        // B3 is ~4-5x B0 in the published table.
        let ratio = m3 as f64 / m0 as f64;
        assert!((2.5..7.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn se_swish_variant_adds_ops_not_many_macs() {
        let plain = efficientnet(0, false);
        let se = efficientnet(0, true);
        assert!(se.layers.len() > plain.layers.len());
        let extra = se.total_macs() as f64 / plain.total_macs() as f64;
        assert!(extra < 1.15, "SE/Swish should be cheap in MACs ({extra})");
    }

    #[test]
    fn manual_edgetpu_uses_fused_early_ibn_late() {
        let net = manual_edgetpu(false);
        let first_dw = net
            .layers
            .iter()
            .position(|l| matches!(l.op, Layer::DwConv { .. }))
            .unwrap();
        // No depthwise before layer `first_dw`; at least one 3x3+ full
        // conv with cout>cin (a fused expansion) before it.
        let has_fused_early = net.layers[..first_dw].iter().any(|l| match l.op {
            Layer::Conv2d { kh, cin, cout, .. } => kh >= 3 && cout > cin && cin > 3,
            _ => false,
        });
        assert!(has_fused_early);
        assert!(net.total_macs() > mobilenet_v2(1.0).total_macs());
    }

    #[test]
    fn medium_bigger_than_small() {
        assert!(
            manual_edgetpu(true).total_macs() > manual_edgetpu(false).total_macs()
        );
    }

    #[test]
    fn all_baselines_simulate_on_baseline_hw() {
        use crate::accel::{simulate_network, AcceleratorConfig};
        for (name, net) in all_baselines() {
            let r = simulate_network(&AcceleratorConfig::baseline(), &net)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(r.latency_ms > 0.01 && r.latency_ms < 20.0, "{name}: {r:?}");
        }
    }
}
