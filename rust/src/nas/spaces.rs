//! Search-space definitions and decision-vector decoding.

use crate::model::{Layer, NetworkIr};
use crate::util::Rng;

/// One categorical decision exposed to the controllers.
#[derive(Clone, Debug)]
pub struct DecisionSpec {
    pub name: String,
    pub cardinality: usize,
}

/// Which NAS space (paper §3.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NasSpaceId {
    /// S1: MobileNetV2 backbone, 17 IBN blocks, search k + expansion.
    MobileNetV2,
    /// S2: EfficientNet-B0 backbone, 16 IBN blocks, search k + expansion.
    EfficientNet,
    /// S3: evolved space (§3.2.2): switchable IBN/Fused-IBN + k +
    /// expansion + filter multiplier + groups.
    Evolved,
    /// The 5-block trainable proxy space that maps 1:1 onto the AOT
    /// supernet artifact (DESIGN.md §Substitutions).
    Proxy,
}

pub const KERNEL_SIZES: [usize; 3] = [3, 5, 7];
pub const EXPANSIONS: [usize; 2] = [3, 6];
pub const FILTER_MULTS: [f64; 4] = [0.5, 0.75, 1.0, 1.25];
pub const PROXY_FILTER_MULTS: [f64; 3] = [0.5, 0.75, 1.0];
pub const GROUPS: [usize; 2] = [1, 2];
/// Global compound-scaling coefficients of the evolved space (paper
/// Fig. 4: "NAHAS respects EfficientNet's compound scaling ratios"):
/// (width mult, depth mult, input resolution) for B0..B3-class scaling.
pub const COMPOUND_SCALES: [(f64, f64, usize); 4] =
    [(1.0, 1.0, 224), (1.0, 1.1, 240), (1.1, 1.2, 260), (1.2, 1.4, 300)];

/// A backbone block slot: allocated output width and stride.
#[derive(Clone, Copy, Debug)]
pub struct BlockDef {
    pub cout: usize,
    pub stride: usize,
}

/// Proxy supernet constants — MUST mirror python/compile/config.py (the
/// manifest carries them too; `runtime::Manifest::check_proxy_consts`
/// asserts agreement at startup).
pub const PROXY_BLOCKS: usize = 5;
pub const PROXY_WIDTHS: [usize; 5] = [8, 16, 16, 32, 32];
pub const PROXY_STRIDES: [usize; 5] = [1, 2, 1, 2, 1];
pub const PROXY_STEM: usize = 8;
pub const PROXY_IMG: usize = 8;
pub const PROXY_CMAX: usize = 32;
pub const PROXY_CEXP_MAX: usize = 192;
pub const PROXY_MAX_EXPANSION: usize = 6;

fn mobilenet_v2_blocks() -> Vec<BlockDef> {
    // (t, c, n, s) table of MobileNetV2, expanded to 17 block slots.
    let spec: [(usize, usize, usize); 7] = [
        (16, 1, 1),
        (24, 2, 2),
        (32, 3, 2),
        (64, 4, 2),
        (96, 3, 1),
        (160, 3, 2),
        (320, 1, 1),
    ];
    expand_blocks(&spec)
}

fn efficientnet_b0_blocks() -> Vec<BlockDef> {
    // EfficientNet-B0 MBConv stages expanded to 16 block slots.
    let spec: [(usize, usize, usize); 7] = [
        (16, 1, 1),
        (24, 2, 2),
        (40, 2, 2),
        (80, 3, 2),
        (112, 3, 1),
        (192, 4, 2),
        (320, 1, 1),
    ];
    expand_blocks(&spec)
}

fn expand_blocks(spec: &[(usize, usize, usize)]) -> Vec<BlockDef> {
    let mut out = Vec::new();
    for &(c, n, s) in spec {
        for i in 0..n {
            out.push(BlockDef { cout: c, stride: if i == 0 { s } else { 1 } });
        }
    }
    out
}

fn proxy_blocks() -> Vec<BlockDef> {
    PROXY_WIDTHS
        .iter()
        .zip(PROXY_STRIDES.iter())
        .map(|(&c, &s)| BlockDef { cout: c, stride: s })
        .collect()
}

/// A NAS search space: block skeleton + decision layout.
#[derive(Clone, Debug)]
pub struct NasSpace {
    pub id: NasSpaceId,
    pub blocks: Vec<BlockDef>,
    specs: Vec<DecisionSpec>,
    /// Decisions per block (k, exp, [op, filt, groups]).
    per_block: usize,
}

impl NasSpace {
    pub fn new(id: NasSpaceId) -> Self {
        let blocks = match id {
            NasSpaceId::MobileNetV2 => mobilenet_v2_blocks(),
            NasSpaceId::EfficientNet => efficientnet_b0_blocks(),
            NasSpaceId::Evolved => efficientnet_b0_blocks(),
            NasSpaceId::Proxy => proxy_blocks(),
        };
        let per_block = match id {
            NasSpaceId::MobileNetV2 | NasSpaceId::EfficientNet => 2,
            NasSpaceId::Evolved => 5,
            NasSpaceId::Proxy => 4,
        };
        let mut specs = Vec::new();
        if id == NasSpaceId::Evolved {
            // Global compound-scaling decision (paper Fig. 4).
            specs.push(DecisionSpec {
                name: "global/compound_scale".into(),
                cardinality: COMPOUND_SCALES.len(),
            });
        }
        for (b, _) in blocks.iter().enumerate() {
            specs.push(DecisionSpec { name: format!("b{b}/kernel"), cardinality: 3 });
            specs.push(DecisionSpec { name: format!("b{b}/expansion"), cardinality: 2 });
            match id {
                NasSpaceId::Evolved => {
                    specs.push(DecisionSpec { name: format!("b{b}/op"), cardinality: 2 });
                    specs.push(DecisionSpec { name: format!("b{b}/filter"), cardinality: 4 });
                    specs.push(DecisionSpec { name: format!("b{b}/groups"), cardinality: 2 });
                }
                NasSpaceId::Proxy => {
                    specs.push(DecisionSpec { name: format!("b{b}/op"), cardinality: 2 });
                    specs.push(DecisionSpec { name: format!("b{b}/filter"), cardinality: 3 });
                }
                _ => {}
            }
        }
        NasSpace { id, blocks, specs, per_block }
    }

    pub fn specs(&self) -> &[DecisionSpec] {
        &self.specs
    }

    pub fn num_decisions(&self) -> usize {
        self.specs.len()
    }

    /// log10 of the space cardinality (paper: S1 ~ 8.4e12, S2 ~ 1.4e12
    /// after fixing the first block's expansion — we keep every block
    /// searchable, which is a slightly larger space).
    pub fn log10_cardinality(&self) -> f64 {
        self.specs.iter().map(|s| (s.cardinality as f64).log10()).sum()
    }

    pub fn random(&self, rng: &mut Rng) -> Vec<usize> {
        self.specs.iter().map(|s| rng.below(s.cardinality)).collect()
    }

    /// Decisions before the per-block slices (the evolved space's global
    /// compound-scale knob).
    fn global_decisions(&self) -> usize {
        if self.id == NasSpaceId::Evolved {
            1
        } else {
            0
        }
    }

    /// Per-block decision slice: (k_idx, exp_idx, op_idx, filt_idx, g_idx).
    fn block_decisions(&self, d: &[usize], b: usize) -> (usize, usize, usize, usize, usize) {
        let base = self.global_decisions() + b * self.per_block;
        let k = d[base];
        let e = d[base + 1];
        match self.id {
            NasSpaceId::Evolved => (k, e, d[base + 2], d[base + 3], d[base + 4]),
            NasSpaceId::Proxy => (k, e, d[base + 2], d[base + 3], 0),
            _ => (k, e, 0, 2, 0), // IBN, filter x1.0
        }
    }

    /// Decode a decision vector into the simulator IR.
    pub fn decode(&self, d: &[usize]) -> NetworkIr {
        let mut net = NetworkIr::default();
        self.decode_into(d, &mut net);
        net
    }

    /// [`NasSpace::decode`] into a caller-owned buffer, reusing its
    /// allocations (the batch evaluation hot path decodes thousands of
    /// networks into one scratch IR instead of allocating each).
    /// Bit-identical to `decode` — it *is* `decode`'s body.
    pub fn decode_into(&self, d: &[usize], net: &mut NetworkIr) {
        assert_eq!(d.len(), self.specs.len(), "decision vector length");
        match self.id {
            NasSpaceId::Proxy => self.decode_proxy_ir(d, net),
            _ => self.decode_imagenet_ir(d, net),
        }
    }

    fn decode_imagenet_ir(&self, d: &[usize], net: &mut NetworkIr) {
        // Evolved space: global compound scaling (width/depth/resolution).
        let (wm, dm, res) = if self.global_decisions() == 1 {
            COMPOUND_SCALES[d[0]]
        } else {
            (1.0, 1.0, 224)
        };
        let (stem, head_ch, classes) = (scale_ch(32, wm), 1280, 1000);
        net.reset(self.space_name(), res, res, 3);
        net.push(Layer::Conv2d { kh: 3, kw: 3, cin: 3, cout: stem, stride: 2, groups: 1 });
        // Depth multiplier: round(S * (dm - 1)) extra stride-1 repeats,
        // assigned to the deepest stride-1 slots (compound-scaling
        // convention; deepest blocks are spatially cheapest). A block's
        // repeat count depends only on its rank among the stride-1
        // slots, so the walk below needs no slot list allocation.
        let s1_count = (1..self.blocks.len()).filter(|&b| self.blocks[b].stride == 1).count();
        let extra = ((s1_count as f64) * (dm - 1.0)).round() as usize;
        let deep_from = s1_count.saturating_sub(extra);
        let mut s1_rank = 0;
        for (b, def) in self.blocks.iter().enumerate() {
            let (ki, ei, op, fi, gi) = self.block_decisions(d, b);
            let k = KERNEL_SIZES[ki];
            // First block runs expansion 1 (both backbones).
            let e = if b == 0 { 1 } else { EXPANSIONS[ei] };
            let cout = scale_ch(def.cout, FILTER_MULTS[fi] * wm);
            let deep = b >= 1 && def.stride == 1 && {
                s1_rank += 1;
                s1_rank - 1 >= deep_from
            };
            let reps = if deep { 2 } else { 1 };
            for r in 0..reps {
                let stride = if r == 0 { def.stride } else { 1 };
                if op == 1 {
                    net.push_fused_ibn(k, e, cout, stride, GROUPS[gi]);
                } else {
                    net.push_ibn(k, e, cout, stride);
                }
            }
        }
        let c = net.cur_c();
        net.push(Layer::Conv2d { kh: 1, kw: 1, cin: c, cout: head_ch, stride: 1, groups: 1 });
        net.push(Layer::GlobalPool { c: head_ch });
        net.push(Layer::Dense { cin: head_ch, cout: classes });
    }

    fn decode_proxy_ir(&self, d: &[usize], net: &mut NetworkIr) {
        net.reset("proxy", PROXY_IMG, PROXY_IMG, 3);
        net.push(Layer::Conv2d { kh: 3, kw: 3, cin: 3, cout: PROXY_STEM, stride: 1, groups: 1 });
        for (b, def) in self.blocks.iter().enumerate() {
            let (ki, ei, op, fi, _) = self.block_decisions(d, b);
            let k = KERNEL_SIZES[ki];
            let e = EXPANSIONS[ei];
            let cout = scale_ch(def.cout, PROXY_FILTER_MULTS[fi]);
            if op == 1 {
                net.push_fused_ibn(k, e, cout, def.stride, 1);
            } else {
                net.push_ibn(k, e, cout, def.stride);
            }
        }
        let c = net.cur_c();
        net.push(Layer::GlobalPool { c });
        net.push(Layer::Dense { cin: c, cout: 16 });
    }

    fn space_name(&self) -> &'static str {
        match self.id {
            NasSpaceId::MobileNetV2 => "s1-mobilenetv2",
            NasSpaceId::EfficientNet => "s2-efficientnet",
            NasSpaceId::Evolved => "s3-evolved",
            NasSpaceId::Proxy => "proxy",
        }
    }

    /// Decode a Proxy-space decision vector into the dense masks the AOT
    /// supernet artifact takes as inputs (layouts must match model.py).
    pub fn decode_masks(&self, d: &[usize]) -> ProxyMasks {
        assert_eq!(self.id, NasSpaceId::Proxy, "masks exist only for the proxy space");
        let nb = PROXY_BLOCKS;
        let mut m = ProxyMasks {
            opsel: vec![0.0; nb * 2],
            ksel: vec![0.0; nb * 3],
            expmask: vec![0.0; nb * PROXY_CEXP_MAX],
            outmask: vec![0.0; nb * PROXY_CMAX],
        };
        let cins: Vec<usize> =
            std::iter::once(PROXY_STEM).chain(PROXY_WIDTHS[..nb - 1].iter().copied()).collect();
        for b in 0..nb {
            let (ki, ei, op, fi, _) = self.block_decisions(d, b);
            m.opsel[b * 2 + op] = 1.0;
            m.ksel[b * 3 + ki] = 1.0;
            let cexp = cins[b] * EXPANSIONS[ei];
            for j in 0..cexp {
                m.expmask[b * PROXY_CEXP_MAX + j] = 1.0;
            }
            let cout = scale_ch(PROXY_WIDTHS[b], PROXY_FILTER_MULTS[fi]);
            for j in 0..cout {
                m.outmask[b * PROXY_CMAX + j] = 1.0;
            }
        }
        m
    }
}

/// Round a scaled channel count to a multiple of 4 (hardware-friendly),
/// minimum 4.
pub fn scale_ch(c: usize, mult: f64) -> usize {
    (((c as f64 * mult / 4.0).round() as usize) * 4).max(4)
}

/// Dense mask encoding of one proxy-space sample (artifact inputs).
#[derive(Clone, Debug)]
pub struct ProxyMasks {
    pub opsel: Vec<f32>,
    pub ksel: Vec<f32>,
    pub expmask: Vec<f32>,
    pub outmask: Vec<f32>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    #[test]
    fn space_sizes_match_paper() {
        assert_eq!(NasSpace::new(NasSpaceId::MobileNetV2).blocks.len(), 17);
        assert_eq!(NasSpace::new(NasSpaceId::EfficientNet).blocks.len(), 16);
        assert_eq!(NasSpace::new(NasSpaceId::Proxy).blocks.len(), 5);
        // Paper: |S1| ~ 8.4e12 (with block 0's expansion fixed); ours
        // keeps all expansion bits so log10 is slightly above.
        let s1 = NasSpace::new(NasSpaceId::MobileNetV2).log10_cardinality();
        assert!((12.0..14.5).contains(&s1), "log10|S1| = {s1}");
        let s3 = NasSpace::new(NasSpaceId::Evolved).log10_cardinality();
        assert!(s3 > s1, "evolved space must be bigger");
    }

    #[test]
    fn decode_mobilenetv2_shape() {
        let sp = NasSpace::new(NasSpaceId::MobileNetV2);
        let d = vec![0; sp.num_decisions()];
        let net = sp.decode(&d);
        // stem + blocks + head conv + pool + fc
        assert!(net.layers.len() > 17 * 2);
        assert_eq!(net.input_h, 224);
        // k=3, exp=3 everywhere: MACs in the vicinity of MobileNetV2.
        let m = net.total_macs();
        assert!((100e6..800e6).contains(&(m as f64)), "macs {m}");
    }

    #[test]
    fn bigger_decisions_give_bigger_models() {
        let sp = NasSpace::new(NasSpaceId::EfficientNet);
        let small: Vec<usize> = (0..sp.num_decisions()).map(|_| 0).collect();
        let big: Vec<usize> =
            sp.specs().iter().map(|s| s.cardinality - 1).collect();
        assert!(sp.decode(&big).total_macs() > sp.decode(&small).total_macs());
    }

    #[test]
    fn evolved_space_emits_fused_blocks() {
        let sp = NasSpace::new(NasSpaceId::Evolved);
        let mut d = vec![0; sp.num_decisions()];
        // All blocks op=Fused (decision 0 is the global compound scale).
        for b in 0..sp.blocks.len() {
            d[1 + b * 5 + 2] = 1;
        }
        let net = sp.decode(&d);
        let dw_count = net
            .layers
            .iter()
            .filter(|l| matches!(l.op, crate::model::Layer::DwConv { .. }))
            .count();
        assert_eq!(dw_count, 0, "fused blocks must not contain depthwise convs");
    }

    #[test]
    fn proxy_masks_match_ir() {
        let sp = NasSpace::new(NasSpaceId::Proxy);
        let d = sp.random(&mut crate::util::Rng::new(9));
        let m = sp.decode_masks(&d);
        assert_eq!(m.opsel.len(), 10);
        assert_eq!(m.ksel.len(), 15);
        assert_eq!(m.expmask.len(), 5 * PROXY_CEXP_MAX);
        assert_eq!(m.outmask.len(), 5 * PROXY_CMAX);
        // Each block: exactly one op and one kernel selected.
        for b in 0..5 {
            assert_eq!(m.opsel[b * 2] + m.opsel[b * 2 + 1], 1.0);
            assert_eq!(m.ksel[b * 3..b * 3 + 3].iter().sum::<f32>(), 1.0);
        }
    }

    #[test]
    fn prop_decode_random_vectors() {
        for id in [
            NasSpaceId::MobileNetV2,
            NasSpaceId::EfficientNet,
            NasSpaceId::Evolved,
            NasSpaceId::Proxy,
        ] {
            let sp = NasSpace::new(id);
            proptest::check(
                "decode sane",
                64,
                |r| sp.random(r),
                |d| {
                    let net = sp.decode(d);
                    if net.total_macs() == 0 {
                        return Err("zero macs".into());
                    }
                    if net.total_params() == 0 {
                        return Err("zero params".into());
                    }
                    Ok(())
                },
            );
        }
    }

    #[test]
    fn prop_expmask_counts_match_expansion() {
        let sp = NasSpace::new(NasSpaceId::Proxy);
        proptest::check(
            "expmask count",
            64,
            |r| sp.random(r),
            |d| {
                let m = sp.decode_masks(d);
                let cins = [PROXY_STEM, 8, 16, 16, 32];
                for b in 0..5 {
                    let e = EXPANSIONS[d[b * 4 + 1]];
                    let want = (cins[b] * e) as f32;
                    let got: f32 =
                        m.expmask[b * PROXY_CEXP_MAX..(b + 1) * PROXY_CEXP_MAX].iter().sum();
                    if got != want {
                        return Err(format!("block {b}: {got} vs {want}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn scale_ch_rounds_to_multiple_of_4() {
        assert_eq!(scale_ch(16, 0.5), 8);
        assert_eq!(scale_ch(24, 0.75), 20); // 18 -> round(4.5)*4 = 20
        assert_eq!(scale_ch(16, 1.25), 20);
        assert_eq!(scale_ch(4, 0.5), 4); // floor at 4
    }
}
