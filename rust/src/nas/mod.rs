//! NAS search spaces (paper §3.2).
//!
//! * [`spaces`] — S1 (MobileNetV2-based, §3.2.1), S2 (EfficientNet-B0
//!   based, §3.2.1), S3 (the *evolved* space with switchable Fused-IBN
//!   layers, filter multipliers and groups, §3.2.2), and the small
//!   `Proxy` space that maps 1:1 onto the trainable AOT supernet.
//! * [`baselines`] — the fixed reference models of Table 3 / Fig. 8
//!   (MobileNetV2, EfficientNet-B0/B1/B3 w/o SE+Swish, MnasNet-like,
//!   ProxylessNAS-like, MobileNetV3-like, Manual-EdgeTPU-S/M).
//!
//! Every space exposes a flat vector of categorical decisions — the
//! common currency of the controllers in `search::` — and decodes a
//! decision vector into a [`crate::model::NetworkIr`] the simulator
//! costs.

pub mod baselines;
pub mod spaces;

pub use spaces::{DecisionSpec, NasSpace, NasSpaceId, ProxyMasks};
