//! Pareto-frontier utilities (paper Fig. 2: joint search "extends the
//! Pareto frontier by joining multiple frontiers").
//!
//! Convention: objective 0 is *maximized* (accuracy), objective 1 is
//! *minimized* (latency / energy).

/// One evaluated sample: (maximize, minimize) + an opaque tag.
#[derive(Clone, Debug, PartialEq)]
pub struct Point {
    pub acc: f64,
    pub cost: f64,
    pub tag: String,
}

impl Point {
    pub fn new(acc: f64, cost: f64, tag: impl Into<String>) -> Self {
        Point { acc, cost, tag: tag.into() }
    }

    /// True iff `self` dominates `other` (no worse in both, better in one).
    pub fn dominates(&self, other: &Point) -> bool {
        self.acc >= other.acc
            && self.cost <= other.cost
            && (self.acc > other.acc || self.cost < other.cost)
    }
}

/// Extract the non-dominated subset, sorted by increasing cost.
pub fn frontier(points: &[Point]) -> Vec<Point> {
    let mut sorted: Vec<&Point> = points.iter().collect();
    // Sort by cost asc, acc desc: then a sweep keeping the running max
    // accuracy yields the frontier in O(n log n). `total_cmp` keeps the
    // sort total when a degenerate reward config produces NaN metrics:
    // NaN costs sort last (after +inf) and NaN accuracies sort below
    // every real accuracy, so they never abort the sort. NaN points
    // sit outside the dominance order entirely, so neither coordinate
    // may put one on the frontier: a NaN accuracy fails the
    // `> best_acc` sweep by itself, and a NaN cost is skipped
    // explicitly below.
    sorted.sort_by(|a, b| a.cost.total_cmp(&b.cost).then(b.acc.total_cmp(&a.acc)));
    let mut out: Vec<Point> = Vec::new();
    let mut best_acc = f64::NEG_INFINITY;
    for p in sorted {
        if p.acc > best_acc && !p.cost.is_nan() {
            out.push(p.clone());
            best_acc = p.acc;
        }
    }
    out
}

/// Hypervolume (area) dominated w.r.t. a reference (acc_ref, cost_ref)
/// with acc >= acc_ref... standard 2-D: sum over frontier steps of
/// (acc - acc_ref) x (cost_ref - cost), cost_ref an upper bound.
pub fn hypervolume(points: &[Point], acc_ref: f64, cost_ref: f64) -> f64 {
    let front = frontier(points);
    let mut hv = 0.0;
    let mut prev_acc = acc_ref;
    // Walk from cheapest to most expensive; each step adds the rectangle
    // of its accuracy improvement across the remaining cost span.
    for p in &front {
        if p.cost >= cost_ref || p.acc <= prev_acc {
            continue;
        }
        hv += (p.acc - prev_acc) * (cost_ref - p.cost);
        prev_acc = p.acc;
    }
    hv
}

/// Merge several frontiers (Fig. 2: the joint-search frontier is the
/// frontier of the union of per-hardware frontiers).
pub fn union_frontier(frontiers: &[Vec<Point>]) -> Vec<Point> {
    let all: Vec<Point> = frontiers.iter().flatten().cloned().collect();
    frontier(&all)
}

/// One evaluated sample with N minimized cost axes (e.g. latency,
/// energy, area) next to the maximized accuracy. The 2-D [`Point`] API
/// above stays untouched — N-dim frontiers are a reporting layer for
/// multi-objective scenarios, never part of the search trajectory.
#[derive(Clone, Debug, PartialEq)]
pub struct MultiPoint {
    pub acc: f64,
    pub costs: Vec<f64>,
    pub tag: String,
}

impl MultiPoint {
    pub fn new(acc: f64, costs: Vec<f64>, tag: impl Into<String>) -> Self {
        MultiPoint { acc, costs, tag: tag.into() }
    }

    /// True iff `self` dominates `other`: no worse on every axis
    /// (acc maximized, every cost minimized) and strictly better on at
    /// least one. Points of mismatched dimensionality never dominate.
    pub fn dominates(&self, other: &MultiPoint) -> bool {
        if self.costs.len() != other.costs.len() {
            return false;
        }
        let no_worse = self.acc >= other.acc
            && self.costs.iter().zip(&other.costs).all(|(a, b)| a <= b);
        let better = self.acc > other.acc
            || self.costs.iter().zip(&other.costs).any(|(a, b)| a < b);
        no_worse && better
    }
}

/// Extract the non-dominated subset of N-objective points. O(n^2)
/// pairwise sweep — frontiers here are reporting-sized (hundreds, not
/// millions). Deterministic: output order follows the input order.
pub fn frontier_nd(points: &[MultiPoint]) -> Vec<MultiPoint> {
    let mut out: Vec<MultiPoint> = Vec::new();
    for (i, p) in points.iter().enumerate() {
        let dominated = points.iter().enumerate().any(|(j, q)| {
            // An exact duplicate is kept once: only the earliest copy
            // survives (later copies are "dominated" by index order).
            q.dominates(p) || (j < i && q == p)
        });
        if !dominated {
            out.push(p.clone());
        }
    }
    out
}

/// Merge several N-objective frontiers into one.
pub fn union_frontier_nd(frontiers: &[Vec<MultiPoint>]) -> Vec<MultiPoint> {
    let all: Vec<MultiPoint> = frontiers.iter().flatten().cloned().collect();
    frontier_nd(&all)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::Rng;

    fn p(acc: f64, cost: f64) -> Point {
        Point::new(acc, cost, "")
    }

    #[test]
    fn dominance_basics() {
        assert!(p(0.8, 1.0).dominates(&p(0.7, 1.0)));
        assert!(p(0.8, 1.0).dominates(&p(0.8, 2.0)));
        assert!(!p(0.8, 1.0).dominates(&p(0.8, 1.0)));
        assert!(!p(0.9, 2.0).dominates(&p(0.8, 1.0)));
    }

    #[test]
    fn frontier_filters_dominated() {
        let pts = vec![p(0.7, 1.0), p(0.8, 2.0), p(0.75, 3.0), p(0.9, 4.0)];
        let f = frontier(&pts);
        let tags: Vec<(f64, f64)> = f.iter().map(|q| (q.acc, q.cost)).collect();
        assert_eq!(tags, vec![(0.7, 1.0), (0.8, 2.0), (0.9, 4.0)]);
    }

    #[test]
    fn union_extends_frontier() {
        // Two hardware configs with different sweet spots (Fig. 2).
        let hw1 = vec![p(0.70, 0.3), p(0.75, 0.6)];
        let hw2 = vec![p(0.72, 0.4), p(0.80, 1.0)];
        let joint = union_frontier(&[hw1.clone(), hw2.clone()]);
        let hv1 = hypervolume(&hw1, 0.5, 2.0);
        let hv2 = hypervolume(&hw2, 0.5, 2.0);
        let hvj = hypervolume(&joint, 0.5, 2.0);
        assert!(hvj >= hv1.max(hv2));
        assert_eq!(joint.len(), 4); // all four are mutually non-dominated
    }

    #[test]
    fn prop_frontier_is_mutually_nondominated_and_complete() {
        proptest::check(
            "frontier invariants",
            128,
            |r: &mut Rng| {
                (0..(2 + r.below(40)))
                    .map(|i| Point::new(r.f64(), r.f64(), format!("{i}")))
                    .collect::<Vec<_>>()
            },
            |pts| {
                let f = frontier(pts);
                for a in &f {
                    for b in &f {
                        if a != b && a.dominates(b) {
                            return Err(format!("{a:?} dominates {b:?} in frontier"));
                        }
                    }
                }
                // Every input point is dominated by (or equal to) some
                // frontier point.
                for q in pts {
                    let covered =
                        f.iter().any(|fp| fp.dominates(q) || (fp.acc, fp.cost) == (q.acc, q.cost));
                    if !covered {
                        return Err(format!("{q:?} not covered"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn hypervolume_monotone_under_additions() {
        let mut pts = vec![p(0.7, 1.0)];
        let hv0 = hypervolume(&pts, 0.0, 2.0);
        pts.push(p(0.9, 1.5));
        assert!(hypervolume(&pts, 0.0, 2.0) > hv0);
    }

    #[test]
    fn hypervolume_of_empty_input_is_zero() {
        assert_eq!(hypervolume(&[], 0.0, 1.0), 0.0);
    }

    #[test]
    fn hypervolume_ignores_points_outside_the_reference_box() {
        // Too expensive (cost >= cost_ref) or no better than the
        // accuracy reference: zero dominated area.
        let outside = vec![p(0.9, 2.0), p(0.95, 3.5), p(0.3, 0.5), p(0.5, 0.2)];
        assert_eq!(hypervolume(&outside, 0.5, 2.0), 0.0);
        // One point inside the box contributes exactly its rectangle,
        // regardless of the outside points.
        let mut pts = outside;
        pts.push(p(0.8, 1.0));
        let hv = hypervolume(&pts, 0.5, 2.0);
        assert!((hv - (0.8 - 0.5) * (2.0 - 1.0)).abs() < 1e-12, "hv {hv}");
    }

    #[test]
    fn hypervolume_counts_duplicate_cost_points_once() {
        // Three candidates at the same cost: only the best-accuracy
        // one is on the frontier, so the area must not double-count.
        let dup = vec![p(0.6, 1.0), p(0.8, 1.0), p(0.7, 1.0)];
        let hv = hypervolume(&dup, 0.5, 2.0);
        assert!((hv - (0.8 - 0.5) * (2.0 - 1.0)).abs() < 1e-12, "hv {hv}");
        // Exact duplicates of the best point change nothing either.
        let twice = vec![p(0.8, 1.0), p(0.8, 1.0)];
        assert_eq!(hypervolume(&twice, 0.5, 2.0), hv);
    }

    fn mp(acc: f64, costs: &[f64]) -> MultiPoint {
        MultiPoint::new(acc, costs.to_vec(), "")
    }

    #[test]
    fn nd_dominance_basics() {
        assert!(mp(0.8, &[1.0, 2.0]).dominates(&mp(0.7, &[1.0, 2.0])));
        assert!(mp(0.8, &[1.0, 2.0]).dominates(&mp(0.8, &[1.5, 2.0])));
        assert!(!mp(0.8, &[1.0, 2.0]).dominates(&mp(0.8, &[1.0, 2.0])));
        // Better on one axis, worse on another: incomparable.
        assert!(!mp(0.8, &[1.0, 3.0]).dominates(&mp(0.7, &[2.0, 2.0])));
        // Mismatched dimensionality never dominates.
        assert!(!mp(0.9, &[0.1]).dominates(&mp(0.1, &[5.0, 5.0])));
    }

    #[test]
    fn nd_frontier_matches_2d_on_one_cost_axis() {
        let pts2 = vec![p(0.7, 1.0), p(0.8, 2.0), p(0.75, 3.0), p(0.9, 4.0)];
        let ptsn: Vec<MultiPoint> =
            pts2.iter().map(|q| mp(q.acc, &[q.cost])).collect();
        let f2: Vec<(f64, f64)> =
            frontier(&pts2).iter().map(|q| (q.acc, q.cost)).collect();
        let mut fn_: Vec<(f64, f64)> =
            frontier_nd(&ptsn).iter().map(|q| (q.acc, q.costs[0])).collect();
        fn_.sort_by(|a, b| a.1.total_cmp(&b.1));
        assert_eq!(f2, fn_);
    }

    #[test]
    fn nd_frontier_keeps_axis_tradeoffs() {
        // Each point is best on one axis: all three survive, plus the
        // dominated fourth is dropped and the duplicate kept once.
        let pts = vec![
            mp(0.9, &[3.0, 3.0, 1.0]),
            mp(0.8, &[1.0, 3.0, 3.0]),
            mp(0.7, &[3.0, 1.0, 3.0]),
            mp(0.6, &[3.0, 3.0, 3.0]),
            mp(0.9, &[3.0, 3.0, 1.0]),
        ];
        let f = frontier_nd(&pts);
        assert_eq!(f.len(), 3);
        assert!(f.iter().all(|q| q.acc >= 0.7));
    }

    #[test]
    fn prop_nd_frontier_is_mutually_nondominated_and_complete() {
        proptest::check(
            "frontier_nd invariants",
            128,
            |r: &mut Rng| {
                (0..(2 + r.below(30)))
                    .map(|i| {
                        MultiPoint::new(r.f64(), vec![r.f64(), r.f64(), r.f64()], format!("{i}"))
                    })
                    .collect::<Vec<_>>()
            },
            |pts| {
                let f = frontier_nd(pts);
                for a in &f {
                    for b in &f {
                        if a != b && a.dominates(b) {
                            return Err(format!("{a:?} dominates {b:?} in frontier"));
                        }
                    }
                }
                for q in pts {
                    let covered = f.iter().any(|fp| fp.dominates(q) || fp == q);
                    if !covered {
                        return Err(format!("{q:?} not covered"));
                    }
                }
                // Idempotency of the union on its own output.
                let twice = union_frontier_nd(&[f.clone()]);
                if twice != f {
                    return Err("union_frontier_nd not idempotent".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_union_frontier_is_idempotent() {
        proptest::check(
            "union_frontier idempotent",
            128,
            |r: &mut Rng| {
                (0..(1 + r.below(4)))
                    .map(|fi| {
                        (0..r.below(16))
                            .map(|i| Point::new(r.f64(), r.f64(), format!("{fi}.{i}")))
                            .collect::<Vec<_>>()
                    })
                    .collect::<Vec<Vec<Point>>>()
            },
            |fronts| {
                let once = union_frontier(fronts);
                let twice = union_frontier(&[once.clone()]);
                if once != twice {
                    return Err(format!("not idempotent: {once:?} vs {twice:?}"));
                }
                Ok(())
            },
        );
    }
}
