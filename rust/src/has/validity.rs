//! Static hardware validity rules.
//!
//! The paper (§3.3): "the HAS search space contains many invalid points
//! ... the created accelerator configuration in combination with the NAS
//! model may not be supported by the compiler". We model this with two
//! layers of rejection:
//!
//! 1. The *static* rules here — properties of the hardware alone, the
//!    kind a design-rule checker or the compiler's target validator
//!    rejects immediately.
//! 2. The *model-dependent* failures raised by the simulator
//!    ([`crate::accel::SimError::WorkingSetTooLarge`]) when a particular
//!    network cannot be mapped onto an otherwise-legal configuration.

use crate::accel::config::SIMD_WAY;
use crate::accel::AcceleratorConfig;

/// Check static design rules; `Err` carries the human-readable reason.
pub fn validate(c: &AcceleratorConfig) -> Result<(), String> {
    // Register file must hold double-buffered operands for the SIMD
    // datapath (8 B per 4-way unit, two buffers) plus accumulators.
    let min_rf_bytes = c.simd_units * SIMD_WAY * 2 * 2 + c.simd_units * 4;
    if c.register_file_kb * 1024 < min_rf_bytes {
        return Err(format!(
            "register file {} KB cannot feed {} SIMD units",
            c.register_file_kb, c.simd_units
        ));
    }
    // Widest datapaths need register bandwidth: 128-unit lanes require
    // at least a 32 KB RF (port/banking constraint).
    if c.simd_units == 128 && c.register_file_kb < 32 {
        return Err("128 SIMD units require >=32 KB register file".into());
    }
    // 8-lane PEs with the widest SIMD exceed the local-memory port
    // budget unless the scratchpad is banked >=2 MB (wiring congestion).
    if c.compute_lanes == 8 && c.simd_units >= 128 && c.local_memory_mb < 2.0 {
        return Err("8 lanes x 128 SIMD needs >=2 MB banked local memory".into());
    }
    // Large PE arrays starve below 10 GB/s (the NoC injection rate the
    // compiler's mapper assumes).
    if c.num_pes() >= 48 && c.io_bandwidth_gbps < 10.0 {
        return Err(format!("{} PEs starve at {} GB/s", c.num_pes(), c.io_bandwidth_gbps));
    }
    // Degenerate chip: a 1x1 array with 1 lane and minimal SIMD cannot
    // sustain the runtime's minimum batch scheduling quantum.
    if c.num_pes() == 1 && c.compute_lanes == 1 && c.simd_units <= 16 {
        return Err("single-PE single-lane 16-SIMD config below runtime minimum".into());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::has::HasSpace;
    use crate::util::Rng;

    #[test]
    fn baseline_is_valid() {
        assert!(validate(&AcceleratorConfig::baseline()).is_ok());
    }

    #[test]
    fn rejects_rf_starved_wide_simd() {
        let mut c = AcceleratorConfig::baseline();
        c.simd_units = 128;
        c.register_file_kb = 8;
        assert!(validate(&c).is_err());
    }

    #[test]
    fn rejects_starved_large_array() {
        let mut c = AcceleratorConfig::baseline();
        c.pe_x = 8;
        c.pe_y = 8;
        c.io_bandwidth_gbps = 5.0;
        assert!(validate(&c).is_err());
    }

    #[test]
    fn rejects_degenerate_chip() {
        let c = AcceleratorConfig {
            pe_x: 1,
            pe_y: 1,
            simd_units: 16,
            compute_lanes: 1,
            local_memory_mb: 0.5,
            register_file_kb: 8,
            io_bandwidth_gbps: 5.0,
        };
        assert!(validate(&c).is_err());
    }

    #[test]
    fn space_contains_many_invalid_points_but_not_mostly() {
        // Paper: "the HAS search space contains many invalid points".
        let sp = HasSpace::new();
        let mut rng = Rng::new(11);
        let total = 5_000;
        let invalid = (0..total)
            .filter(|_| validate(&sp.decode(&sp.random(&mut rng))).is_err())
            .count();
        let frac = invalid as f64 / total as f64;
        assert!((0.01..0.60).contains(&frac), "invalid fraction {frac}");
    }
}
