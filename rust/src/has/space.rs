//! Table-1 HAS space: encode/decode between decision vectors and
//! [`AcceleratorConfig`]s.

use crate::accel::AcceleratorConfig;
use crate::nas::DecisionSpec;
use crate::util::Rng;

pub const PE_DIM: [usize; 5] = [1, 2, 4, 6, 8];
pub const SIMD_UNITS: [usize; 4] = [16, 32, 64, 128];
pub const COMPUTE_LANES: [usize; 4] = [1, 2, 4, 8];
pub const LOCAL_MEMORY_MB: [f64; 5] = [0.5, 1.0, 2.0, 3.0, 4.0];
pub const REGISTER_FILE_KB: [usize; 5] = [8, 16, 32, 64, 128];
pub const IO_BANDWIDTH_GBPS: [f64; 5] = [5.0, 10.0, 15.0, 20.0, 25.0];

/// The seven-knob accelerator search space.
#[derive(Clone, Debug)]
pub struct HasSpace {
    specs: Vec<DecisionSpec>,
}

impl Default for HasSpace {
    fn default() -> Self {
        Self::new()
    }
}

impl HasSpace {
    pub fn new() -> Self {
        let mk = |name: &str, c: usize| DecisionSpec { name: name.into(), cardinality: c };
        HasSpace {
            specs: vec![
                mk("hw/pe_x", PE_DIM.len()),
                mk("hw/pe_y", PE_DIM.len()),
                mk("hw/simd_units", SIMD_UNITS.len()),
                mk("hw/compute_lanes", COMPUTE_LANES.len()),
                mk("hw/local_memory_mb", LOCAL_MEMORY_MB.len()),
                mk("hw/register_file_kb", REGISTER_FILE_KB.len()),
                mk("hw/io_bandwidth_gbps", IO_BANDWIDTH_GBPS.len()),
            ],
        }
    }

    pub fn specs(&self) -> &[DecisionSpec] {
        &self.specs
    }

    pub fn num_decisions(&self) -> usize {
        self.specs.len()
    }

    pub fn random(&self, rng: &mut Rng) -> Vec<usize> {
        self.specs.iter().map(|s| rng.below(s.cardinality)).collect()
    }

    pub fn decode(&self, d: &[usize]) -> AcceleratorConfig {
        assert_eq!(d.len(), 7, "HAS decision vector length");
        AcceleratorConfig {
            pe_x: PE_DIM[d[0]],
            pe_y: PE_DIM[d[1]],
            simd_units: SIMD_UNITS[d[2]],
            compute_lanes: COMPUTE_LANES[d[3]],
            local_memory_mb: LOCAL_MEMORY_MB[d[4]],
            register_file_kb: REGISTER_FILE_KB[d[5]],
            io_bandwidth_gbps: IO_BANDWIDTH_GBPS[d[6]],
        }
    }

    /// The decision vector of the paper's baseline configuration.
    pub fn baseline_decisions(&self) -> Vec<usize> {
        let b = AcceleratorConfig::baseline();
        vec![
            PE_DIM.iter().position(|&v| v == b.pe_x).unwrap(),
            PE_DIM.iter().position(|&v| v == b.pe_y).unwrap(),
            SIMD_UNITS.iter().position(|&v| v == b.simd_units).unwrap(),
            COMPUTE_LANES.iter().position(|&v| v == b.compute_lanes).unwrap(),
            LOCAL_MEMORY_MB.iter().position(|&v| v == b.local_memory_mb).unwrap(),
            REGISTER_FILE_KB.iter().position(|&v| v == b.register_file_kb).unwrap(),
            IO_BANDWIDTH_GBPS.iter().position(|&v| v == b.io_bandwidth_gbps).unwrap(),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    #[test]
    fn seven_knobs_match_table1() {
        let sp = HasSpace::new();
        assert_eq!(sp.num_decisions(), 7);
        let card: usize = sp.specs().iter().map(|s| s.cardinality).product();
        assert_eq!(card, 5 * 5 * 4 * 4 * 5 * 5 * 5); // Table 1 cardinality
    }

    #[test]
    fn baseline_roundtrips() {
        let sp = HasSpace::new();
        let d = sp.baseline_decisions();
        assert_eq!(sp.decode(&d), AcceleratorConfig::baseline());
    }

    #[test]
    fn prop_decode_in_table_ranges() {
        let sp = HasSpace::new();
        proptest::check(
            "has decode",
            proptest::CASES,
            |r| sp.random(r),
            |d| {
                let c = sp.decode(d);
                if !PE_DIM.contains(&c.pe_x) || !PE_DIM.contains(&c.pe_y) {
                    return Err("pe".into());
                }
                if !SIMD_UNITS.contains(&c.simd_units) {
                    return Err("simd".into());
                }
                if !REGISTER_FILE_KB.contains(&c.register_file_kb) {
                    return Err("rf".into());
                }
                Ok(())
            },
        );
    }
}
