//! Hardware Accelerator Search space (paper §3.3, Table 1).
//!
//! Exposes the seven Table-1 knobs as categorical decisions (same
//! currency as `nas::DecisionSpec`, so the joint space is just the
//! concatenation) and the static validity rules that make the HAS space
//! contain "many invalid points" (§3.3) — configurations the
//! compiler/mapper rejects before simulation.

pub mod space;
pub mod validity;

pub use space::HasSpace;
pub use validity::validate;
