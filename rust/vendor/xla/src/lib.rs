//! Offline stub of the `xla` PJRT bindings.
//!
//! The real `xla` crate wraps the XLA/PJRT C++ libraries, which cannot
//! be built in this offline environment. This stub keeps the crate
//! graph compiling with the same API surface the repo uses:
//!
//! * [`Literal`] is a **fully functional** host-side tensor (f32/i32 +
//!   shape + tuples) — construction, reshape and readback all work, so
//!   everything up to program execution behaves normally;
//! * [`PjRtClient::compile`] and [`PjRtLoadedExecutable::execute`]
//!   return a clear "PJRT unavailable in this offline build" error, so
//!   code paths that need the AOT artifacts fail gracefully at runtime
//!   (the artifact-driven tests already skip when `artifacts/` is
//!   absent).
//!
//! Swapping this path dependency for the real bindings in Cargo.toml
//! restores full execution with no source changes.

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT is unavailable in this offline build (vendor/xla is a host-only \
         stub; point Cargo.toml at the real xla bindings to execute AOT artifacts)"
    ))
}

#[derive(Clone, Debug)]
enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host-side tensor literal: flat typed storage + dimensions.
#[derive(Clone, Debug)]
pub struct Literal {
    storage: Storage,
    dims: Vec<i64>,
}

/// Element types the stub supports (the repo moves f32/i32 only).
pub trait NativeType: Copy {
    const NAME: &'static str;
    fn store(data: Vec<Self>) -> Storage;
    fn slice(storage: &Storage) -> Option<&[Self]>;
}

impl NativeType for f32 {
    const NAME: &'static str = "f32";

    fn store(data: Vec<f32>) -> Storage {
        Storage::F32(data)
    }

    fn slice(storage: &Storage) -> Option<&[f32]> {
        match storage {
            Storage::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const NAME: &'static str = "i32";

    fn store(data: Vec<i32>) -> Storage {
        Storage::I32(data)
    }

    fn slice(storage: &Storage) -> Option<&[i32]> {
        match storage {
            Storage::I32(v) => Some(v),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a flat slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { storage: T::store(data.to_vec()), dims: vec![data.len() as i64] }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { storage: T::store(vec![v]), dims: vec![] }
    }

    pub fn element_count(&self) -> usize {
        match &self.storage {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
            Storage::Tuple(t) => t.len(),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Same storage under new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want.max(1) as usize != self.element_count() {
            return Err(Error(format!(
                "reshape: {} elements do not fit {:?}",
                self.element_count(),
                dims
            )));
        }
        Ok(Literal { storage: self.storage.clone(), dims: dims.to_vec() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::slice(&self.storage)
            .map(|s| s.to_vec())
            .ok_or_else(|| Error(format!("to_vec: literal is not {}", T::NAME)))
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::slice(&self.storage)
            .and_then(|s| s.first().copied())
            .ok_or_else(|| Error(format!("get_first_element: empty or not {}", T::NAME)))
    }

    /// Build a tuple literal (what executables return).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        let n = elems.len() as i64;
        Literal { storage: Storage::Tuple(elems), dims: vec![n] }
    }

    /// Unpack a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.storage {
            Storage::Tuple(v) => Ok(v),
            _ => Err(Error("to_tuple: literal is not a tuple".to_string())),
        }
    }
}

/// Parsed HLO module (text is carried but never compiled here).
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text =
            std::fs::read_to_string(path).map_err(|e| Error(format!("reading {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub PJRT client: constructible, but compilation is unavailable.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile"))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrips_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn scalar_and_tuple() {
        let s = Literal::scalar(7i32);
        assert_eq!(s.element_count(), 1);
        assert_eq!(s.get_first_element::<i32>().unwrap(), 7);
        let t = Literal::tuple(vec![s, Literal::scalar(1.5f32)]);
        let elems = t.to_tuple().unwrap();
        assert_eq!(elems.len(), 2);
        assert_eq!(elems[1].get_first_element::<f32>().unwrap(), 1.5);
    }

    #[test]
    fn execution_is_unavailable_but_typed() {
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation::from_proto(&HloModuleProto { text: String::new() });
        let err = client.compile(&comp).unwrap_err();
        assert!(format!("{err:?}").contains("offline"));
    }
}
