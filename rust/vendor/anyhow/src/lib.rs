//! Offline vendored stand-in for the `anyhow` crate.
//!
//! The build environment is fully offline (no crates.io index), so the
//! subset of anyhow this repo actually uses lives here as a path
//! dependency: [`Error`], [`Result`], the [`Context`] extension trait
//! for `Result`/`Option`, and the `anyhow!` / `bail!` macros. The API
//! is call-compatible with anyhow 1.x for that subset, so swapping the
//! path dependency for the real crate is a one-line Cargo.toml change.

use std::fmt::{self, Debug, Display};

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message-chain error: an outermost context message plus the chain
/// of underlying causes, innermost last.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error in an outer context message.
    pub fn context<C: Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The message chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut msgs = vec![self.msg.as_str()];
        let mut cur = self.source.as_deref();
        while let Some(e) = cur {
            msgs.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        msgs.into_iter()
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if self.source.is_some() {
            f.write_str("\n\nCaused by:")?;
        }
        let mut cur = self.source.as_deref();
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

// Like anyhow: `Error` deliberately does NOT implement std::error::Error,
// which is what makes this blanket conversion coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut msgs: Vec<String> = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err = Error::msg(msgs.pop().unwrap());
        while let Some(m) = msgs.pop() {
            err = err.context(m);
        }
        err
    }
}

mod ext {
    use super::Error;

    /// Anything `.context()` can absorb: std errors and [`Error`] itself.
    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> Error {
            Error::from(self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// Attach context to the error arm of a `Result` or to a `None`.
pub trait Context<T> {
    fn context<C: Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: ext::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::Error::msg(format!($msg)) };
    ($fmt:expr, $($arg:tt)*) => { $crate::Error::msg(format!($fmt, $($arg)*)) };
    ($err:expr $(,)?) => { $crate::Error::msg($err) };
}

/// Early-return with an [`Error`] built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return ::core::result::Result::Err($crate::anyhow!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn context_chains_messages() {
        let e = io_err().context("opening config").unwrap_err();
        assert_eq!(e.to_string(), "opening config");
        let chain: Vec<&str> = e.chain().collect();
        assert_eq!(chain, vec!["opening config", "gone"]);
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn with_context_on_option() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
    }

    #[test]
    fn macros_build_errors() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("bad value {}", 7);
            }
            Err(anyhow!("plain"))
        }
        assert_eq!(f(true).unwrap_err().to_string(), "bad value 7");
        assert_eq!(f(false).unwrap_err().to_string(), "plain");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            io_err()?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "gone");
    }
}
