//! Quickstart: the three layers in one page.
//!
//! 1. Load the AOT artifacts and run the L1 pallas matmul through PJRT.
//! 2. Cost a reference model on the baseline accelerator with the
//!    cycle-level simulator.
//! 3. Run a small latency-driven joint NAS+HAS search (surrogate
//!    fidelity) and print the best co-designed pair.
//!
//! Run with: `make artifacts && cargo run --release --example quickstart`

use nahas::accel::{simulate_network, AcceleratorConfig};
use nahas::has::HasSpace;
use nahas::nas::{baselines, NasSpace, NasSpaceId};
use nahas::runtime::{lit_f32, to_vec_f32, Runtime};
use nahas::search::joint::JointLayout;
use nahas::search::ppo::PpoController;
use nahas::search::{joint_search, RewardCfg, SearchCfg, SurrogateSim};

fn main() -> anyhow::Result<()> {
    // --- 1. L1 kernel through the PJRT runtime ------------------------
    let mut rt = Runtime::load(Runtime::default_dir())?;
    let x: Vec<f32> = (0..256).map(|i| (i % 16) as f32 / 16.0).collect();
    let eye: Vec<f32> = (0..256).map(|i| if i % 17 == 0 { 1.0 } else { 0.0 }).collect();
    let out =
        rt.run("quickstart_matmul", &[&lit_f32(&x, &[16, 16])?, &lit_f32(&eye, &[16, 16])?])?;
    let y = to_vec_f32(&out[0])?;
    assert_eq!(x, y, "pallas matmul with identity must round-trip");
    println!("L1: pallas tiled matmul via PJRT ... ok ({} programs loaded)", rt.num_programs());

    // --- 2. Simulator -------------------------------------------------
    let cfg = AcceleratorConfig::baseline();
    let net = baselines::mobilenet_v2(1.0);
    let rep = simulate_network(&cfg, &net).unwrap();
    println!(
        "L3 simulator: MobileNetV2 on the baseline edge accelerator -> {:.3} ms, {:.3} mJ \
         (paper Table 3: 0.30 ms, 0.70 mJ)",
        rep.latency_ms, rep.energy_mj
    );

    // --- 3. Joint search ------------------------------------------------
    let space = NasSpace::new(NasSpaceId::EfficientNet);
    let has = HasSpace::new();
    let (cards, layout) = JointLayout::cards(&space, &has);
    let mut evaluator = SurrogateSim::new(space, 0);
    let mut controller = PpoController::new(&cards);
    let cfg = SearchCfg::new(400, RewardCfg::latency(0.5), 0);
    let out = joint_search(&mut evaluator, &mut controller, &layout, None, None, &cfg);
    let best = out.best_feasible.expect("feasible co-design found");
    println!(
        "NAHAS joint search (400 samples, target 0.5 ms): top-1 {:.1}%, {:.3} ms, {:.3} mJ",
        best.result.acc * 100.0,
        best.result.latency_ms,
        best.result.energy_mj
    );
    let hw = has.decode(&best.has_d);
    println!(
        "  co-designed accelerator: {}x{} PEs, {} lanes, {} SIMD, {} MB, {} KB RF, {} GB/s",
        hw.pe_x,
        hw.pe_y,
        hw.compute_lanes,
        hw.simd_units,
        hw.local_memory_mb,
        hw.register_file_kb,
        hw.io_bandwidth_gbps
    );
    Ok(())
}
