//! END-TO-END driver: the full three-layer system on a real workload.
//!
//! Everything below runs from rust through PJRT — python never executes:
//!
//! 1. **Oneshot joint search** (paper §3.5.2) on the AOT proxy supernet:
//!    REINFORCE warmup + interleaved shared-weight / controller updates,
//!    hardware cost from the cycle-level simulator, ~400 real training
//!    steps on the synthetic classification task. The controller reward
//!    trace is logged.
//! 2. **Retrain the discovered child** from scratch (multi-trial
//!    fidelity) and compare against a random child — the ground-truth
//!    check that the controller found a genuinely better subnetwork.
//! 3. Re-simulate latency/energy of the final co-designed pair vs the
//!    same network on the baseline accelerator, and write
//!    `results/oneshot_e2e.csv`.
//!
//! Run with: `make artifacts && cargo run --release --example oneshot_e2e`

use nahas::accel::simulate_network;
use nahas::has::HasSpace;
use nahas::metrics;
use nahas::nas::{NasSpace, NasSpaceId};
use nahas::runtime::Runtime;
use nahas::search::oneshot::{oneshot_search, OneshotCfg, SimOracle};
use nahas::trainer::ProxyTrainer;
use nahas::util::Rng;

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let rt = Runtime::load(Runtime::default_dir())?;
    let mut trainer = ProxyTrainer::new(rt, 7)?;
    trainer.steps = 60; // retraining budget per child

    let cfg = OneshotCfg {
        warmup_steps: 100,
        search_steps: 300,
        t_latency_ms: 0.02,
        seed: 7,
        ..Default::default()
    };
    println!(
        "[1/3] oneshot joint search: {} warmup + {} search steps, latency target {} ms",
        cfg.warmup_steps, cfg.search_steps, cfg.t_latency_ms
    );
    let mut oracle = SimOracle { space: NasSpace::new(NasSpaceId::Proxy), has: HasSpace::new() };
    let out = oneshot_search(&mut trainer, &mut oracle, &cfg)?;
    let half = out.reward_trace.len() / 2;
    let mean = |s: &[(usize, f64)]| s.iter().map(|x| x.1).sum::<f64>() / s.len().max(1) as f64;
    println!(
        "    controller reward: first-half mean {:.3} -> second-half mean {:.3} ({} updates)",
        mean(&out.reward_trace[..half]),
        mean(&out.reward_trace[half..]),
        out.reward_trace.len()
    );
    println!(
        "    discovered: nas={:?} hw={:?} (supernet acc {:.3})",
        out.best_nas, out.best_has, out.final_acc
    );

    println!("[2/3] retraining the discovered child from scratch (60 steps) ...");
    let acc_found = trainer.train_child(&out.best_nas, 1001)?;
    let space = trainer.space().clone();
    let mut rng = Rng::new(99);
    let random_child = space.random(&mut rng);
    let acc_random = trainer.train_child(&random_child, 1002)?;
    println!("    NAHAS child acc {:.3} vs random child acc {:.3}", acc_found, acc_random);

    println!("[3/3] re-simulating the co-designed pair ...");
    let has = HasSpace::new();
    let hw = has.decode(&out.best_has);
    let net = space.decode(&out.best_nas);
    let rep = simulate_network(&hw, &net)
        .map_err(|e| anyhow::anyhow!("final pair must simulate: {e}"))?;
    let base = simulate_network(&has.decode(&has.baseline_decisions()), &net).unwrap();
    println!(
        "    co-designed hw: {:.4} ms / {:.4} mJ   (same net on baseline hw: {:.4} ms / {:.4} mJ)",
        rep.latency_ms, rep.energy_mj, base.latency_ms, base.energy_mj
    );

    let rows = vec![
        vec![
            "nahas-oneshot".into(),
            format!("{acc_found:.4}"),
            format!("{:.5}", rep.latency_ms),
            format!("{:.5}", rep.energy_mj),
            format!("{:.1}", rep.area_mm2),
        ],
        vec![
            "random-child-baseline-hw".into(),
            format!("{acc_random:.4}"),
            format!("{:.5}", base.latency_ms),
            format!("{:.5}", base.energy_mj),
            String::new(),
        ],
    ];
    metrics::write_csv(
        "results/oneshot_e2e.csv",
        &["config", "accuracy", "latency_ms", "energy_mj", "area_mm2"],
        &rows,
    )?;
    println!("done in {:.1}s — results/oneshot_e2e.csv written", t0.elapsed().as_secs_f64());
    Ok(())
}
