//! Latency-driven NAHAS across the paper's five latency targets
//! (Fig. 8): searches the IBN-only space at tight targets and the
//! evolved (Fused-IBN) space at relaxed ones — reproducing the paper's
//! observation that "a IBN-only search space is good for identifying
//! small, low-latency models while the proposed evolved search space is
//! good for identifying larger, more accurate models".
//!
//! Run with: `cargo run --release --example latency_sweep`

use nahas::bench::Table;
use nahas::has::HasSpace;
use nahas::nas::{NasSpace, NasSpaceId};
use nahas::search::joint::JointLayout;
use nahas::search::ppo::PpoController;
use nahas::search::{joint_search, RewardCfg, SearchCfg, SurrogateSim};

fn search_best(space_id: NasSpaceId, t_ms: f64, samples: usize, seed: u64) -> Option<(f64, f64)> {
    let space = NasSpace::new(space_id);
    let has = HasSpace::new();
    let (cards, layout) = JointLayout::cards(&space, &has);
    let mut ev = SurrogateSim::new(space, seed);
    let mut ctl = PpoController::new(&cards);
    let cfg = SearchCfg::new(samples, RewardCfg::latency(t_ms), seed);
    let out = joint_search(&mut ev, &mut ctl, &layout, None, None, &cfg);
    out.best_feasible.map(|b| (b.result.acc * 100.0, b.result.latency_ms))
}

fn main() {
    let names = ["NAHAS-XS", "NAHAS-S", "NAHAS-M", "NAHAS-L", "NAHAS-XL"];
    let targets = [0.3, 0.5, 0.8, 1.1, 1.3];
    let mut table = Table::new(&["Model", "Target(ms)", "Space", "Top-1(%)", "Latency(ms)"]);
    for (i, (&t, name)) in targets.iter().zip(names).enumerate() {
        // Tight targets -> IBN-only (S1); relaxed -> evolved (S3).
        let (sid, sname) = if t <= 0.3 {
            (NasSpaceId::MobileNetV2, "IBN-only (S1)")
        } else {
            (NasSpaceId::Evolved, "evolved (S3)")
        };
        match search_best(sid, t, 600, 42 + i as u64) {
            Some((acc, lat)) => table.row(vec![
                name.to_string(),
                format!("{t}"),
                sname.to_string(),
                format!("{acc:.1}"),
                format!("{lat:.3}"),
            ]),
            None => table.row(vec![
                name.to_string(),
                format!("{t}"),
                sname.to_string(),
                "-".into(),
                "infeasible".into(),
            ]),
        }
    }
    println!("Latency-driven NAHAS (cf. paper Fig. 8; surrogate fidelity):");
    table.print();
}
