//! Energy-driven NAHAS (Fig. 1): chip energy (power x latency) vs
//! accuracy, joint search vs platform-aware NAS (fixed baseline
//! accelerator) vs manually crafted models.
//!
//! Run with: `cargo run --release --example energy_pareto`

use nahas::accel::{simulate_network, AcceleratorConfig};
use nahas::bench::Table;
use nahas::has::HasSpace;
use nahas::nas::{baselines, NasSpace, NasSpaceId};
use nahas::search::joint::JointLayout;
use nahas::search::ppo::PpoController;
use nahas::search::{joint_search, RewardCfg, SearchCfg, SurrogateSim};
use nahas::trainer::surrogate;

fn main() {
    let mut table = Table::new(&["Config", "Top-1(%)", "Energy(mJ)", "Latency(ms)"]);

    // Manually crafted references through the same simulator.
    let base_hw = AcceleratorConfig::baseline();
    for (name, net) in [
        ("MobileNetV2 (manual)", baselines::mobilenet_v2(1.0)),
        ("Manual-EdgeTPU-S", baselines::manual_edgetpu(false)),
        ("Manual-EdgeTPU-M", baselines::manual_edgetpu(true)),
    ] {
        let rep = simulate_network(&base_hw, &net).unwrap();
        let acc = surrogate::imagenet_accuracy(&net, 0);
        table.row(vec![
            name.into(),
            format!("{acc:.1}"),
            format!("{:.3}", rep.energy_mj),
            format!("{:.3}", rep.latency_ms),
        ]);
    }

    // Searches at three energy targets: joint vs fixed-hardware.
    for (i, &t_mj) in [0.7, 1.0, 1.5].iter().enumerate() {
        let has = HasSpace::new();
        for fixed in [false, true] {
            let space = NasSpace::new(NasSpaceId::Evolved);
            let (cards, layout) = JointLayout::cards(&space, &has);
            let free = if fixed { cards[..layout.nas_len].to_vec() } else { cards };
            let mut ev = SurrogateSim::new(space, 7 + i as u64);
            let mut ctl = PpoController::new(&free);
            let cfg = SearchCfg::new(600, RewardCfg::energy(t_mj), 7 + i as u64);
            let baseline_hw = fixed.then(|| has.baseline_decisions());
            let out =
                joint_search(&mut ev, &mut ctl, &layout, baseline_hw.as_deref(), None, &cfg);
            let label = if fixed {
                format!("platform-aware NAS @ {t_mj} mJ")
            } else {
                format!("NAHAS joint @ {t_mj} mJ")
            };
            match out.best_feasible {
                Some(b) => table.row(vec![
                    label,
                    format!("{:.1}", b.result.acc * 100.0),
                    format!("{:.3}", b.result.energy_mj),
                    format!("{:.3}", b.result.latency_ms),
                ]),
                None => table.row(vec![label, "-".into(), "infeasible".into(), "-".into()]),
            }
        }
    }

    println!("Energy vs accuracy (cf. paper Fig. 1; surrogate fidelity):");
    table.print();
}
