"""L2: the NAHAS proxy-task supernetwork (JAX, build-time only).

A weight-sharing ConvNet whose architectural decisions are *runtime mask
inputs*, so a single AOT-lowered HLO artifact serves both search modes the
paper compares (§3.5):

  * **oneshot** — shared weights, controller-sampled masks per step
    (ProxylessNAS / TuNAS style);
  * **multi-trial** — fresh weights (re-initialised via ``init_fn``), one
    fixed mask per sampled child (MnasNet-style child programs).

Every block is the paper's *switchable Fused-IBN layer* (Fig. 3): a
``one_of`` between a conventional IBN and a Fused-IBN path, plus tunable
kernel size, expansion factor and filter (output-channel) multiplier —
the PyGlove-symbolised knobs of the evolved search space (§3.2.2),
expressed here as dense masks so shapes stay static for AOT:

  * kernel size ∈ {3,5,7}: a one-hot ``ksel`` contracts constant centered
    k×k masks over the allocated 7×7 weights (equivalent to a true k×k
    conv at stride 1; at stride 2 it is the same operator up to 'SAME'
    padding alignment — see tests/test_model.py);
  * expansion ∈ {3,6}: channel mask over the allocated 6× hidden width
    (applied *after* bias+relu so masked lanes are exactly zero);
  * op type: convex selection between the two paths (one-hot in search);
  * filter multiplier: channel mask over the allocated output width.

The classifier head runs on the L1 pallas matmul kernel, putting the
kernel on the differentiated training path of the exported artifact.

This proxy is deliberately small (see config.py and DESIGN.md
§Substitutions): the paper's full 17-block S1 / 16-block S2 spaces are
modelled in the rust ``nas`` module and costed by the rust simulator; this
network is the *trainable* stand-in for the paper's 5-epoch ImageNet proxy
task.
"""

import jax
import jax.numpy as jnp
from jax import lax
from jax.flatten_util import ravel_pytree

from compile import config
from compile.kernels.matmul import matmul

# Block input widths: stem feeds block 0.
CINS = [config.STEM_CH] + config.WIDTHS[:-1]
CEXPS = [config.MAX_EXPANSION * c for c in CINS]

def kernel_mask(ksel_i):
    """Centered k x k spatial mask in the allocated KMAX x KMAX window.

    Built from the *runtime* ``ksel`` one-hot: radius = ksel . (1, 2, 3),
    mask = (|dh| <= r) & (|dw| <= r). IMPORTANT: this must stay a
    runtime-dependent expression — a materialized [3,7,7] mask constant
    (or any iota construction XLA can constant-fold) gets ELIDED by the
    HLO text printer as ``constant({...})`` and silently reconstructed
    as zeros by the rust-side text parser. aot.py hard-fails the build if
    an elided constant ever appears in an exported program.
    """
    r = ksel_i[0] * 1.0 + ksel_i[1] * 2.0 + ksel_i[2] * 3.0
    pos = jnp.abs(lax.iota(jnp.float32, config.KMAX) - (config.KMAX - 1) / 2.0)
    box = (pos[:, None] <= r + 0.25) & (pos[None, :] <= r + 0.25)
    return box.astype(jnp.float32)


def params_template():
    """Allocated (maximum-width) parameter pytree, all zeros."""
    z = jnp.zeros
    blocks = []
    for i in range(config.BLOCKS):
        cin, cout, cexp = CINS[i], config.WIDTHS[i], CEXPS[i]
        k = config.KMAX
        blocks.append(
            {
                # IBN path: expand 1x1 -> depthwise kxk -> project 1x1.
                "w1": z((cin, cexp)),
                "b1": z((cexp,)),
                "dw": z((k, k, 1, cexp)),
                "bdw": z((cexp,)),
                "w2": z((cexp, cout)),
                "b2": z((cout,)),
                # Fused path: full kxk conv -> project 1x1.
                "wf": z((k, k, cin, cexp)),
                "bf": z((cexp,)),
                "w2f": z((cexp, cout)),
                "b2f": z((cout,)),
            }
        )
    return {
        "stem_w": z((3, 3, 3, config.STEM_CH)),
        "stem_b": z((config.STEM_CH,)),
        "blocks": blocks,
        "head_w": z((config.WIDTHS[-1], config.NUM_CLASSES)),
        "head_b": z((config.NUM_CLASSES,)),
    }


_TEMPLATE = params_template()
FLAT_TEMPLATE, unravel = ravel_pytree(_TEMPLATE)
PARAM_COUNT = FLAT_TEMPLATE.shape[0]


def init_fn(seed):
    """He-normal init of the flat parameter vector from an int32 seed.

    Returned alongside zero Adam moment buffers so the rust side can
    feed all three straight into ``train_step``.
    """
    leaves, treedef = jax.tree_util.tree_flatten(_TEMPLATE)
    key = jax.random.PRNGKey(seed)
    out = []
    for i, leaf in enumerate(leaves):
        k = jax.random.fold_in(key, i)
        if leaf.ndim == 1:  # biases
            out.append(jnp.zeros_like(leaf))
        else:
            fan_in = 1
            for d in leaf.shape[:-1]:
                fan_in *= d
            std = (2.0 / fan_in) ** 0.5
            out.append(std * jax.random.normal(k, leaf.shape))
    params = jax.tree_util.tree_unflatten(treedef, out)
    flat, _ = ravel_pytree(params)
    return flat, jnp.zeros_like(flat), jnp.zeros_like(flat)


def _conv1x1(x, w, b):
    n, h, ww, c = x.shape
    y = x.reshape(-1, c) @ w + b
    return y.reshape(n, h, ww, -1)


def _conv(x, w, stride):
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _dwconv(x, w, stride):
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=x.shape[-1],
    )


def rmsnorm_masked(h, em):
    """RMS-normalize over the *active* channels only.

    ``em`` is the 0/1 channel mask. Masked lanes are exactly zero in
    ``h`` and stay zero; dividing by the RMS over active lanes matches a
    plain channel-RMSNorm of the equivalent narrow network, so the
    narrow-network oracle tests still hold. Without normalization the
    BN-free supernet is badly conditioned at small effective widths
    (training diverges or stalls).
    """
    denom = jnp.maximum(em.sum(), 1.0)
    ms = (h * h * em).sum(axis=-1, keepdims=True) / denom
    return h * lax.rsqrt(ms + 1e-6) * em


def rmsnorm(h):
    """Unmasked channel RMSNorm (stem)."""
    ms = (h * h).mean(axis=-1, keepdims=True)
    return h * lax.rsqrt(ms + 1e-6)


def block_forward(x, bp, i, opsel, ksel, expmask, outmask):
    """One switchable IBN/Fused-IBN block (paper Fig. 3) with masks."""
    stride = config.STRIDES[i]
    cin, cout, cexp = CINS[i], config.WIDTHS[i], CEXPS[i]
    km = kernel_mask(ksel[i])
    em = expmask[i, :cexp]

    # IBN path. Masked hidden lanes are re-zeroed after every bias+relu so
    # the path is exactly a narrower network; masked RMSNorm keeps the
    # BN-free stack well-conditioned at every effective width.
    h = rmsnorm_masked(jnp.maximum(_conv1x1(x, bp["w1"], bp["b1"]), 0.0) * em, em)
    dww = bp["dw"] * km[:, :, None, None]
    h = rmsnorm_masked(jnp.maximum(_dwconv(h, dww, stride) + bp["bdw"], 0.0) * em, em)
    y_ibn = _conv1x1(h, bp["w2"], bp["b2"])

    # Fused path: full kxk conv straight from block input.
    wfm = bp["wf"] * km[:, :, None, None]
    h2 = rmsnorm_masked(jnp.maximum(_conv(x, wfm, stride) + bp["bf"], 0.0) * em, em)
    y_fused = _conv1x1(h2, bp["w2f"], bp["b2f"])

    out = opsel[i, 0] * y_ibn + opsel[i, 1] * y_fused
    out = out * outmask[i, :cout]
    if stride == 1 and cin == cout:
        out = out + x
    return out


def forward(params, x, opsel, ksel, expmask, outmask):
    """Supernet logits. ``x`` is ``[N, IMG, IMG, 3]`` NHWC float32."""
    h = rmsnorm(jnp.maximum(_conv(x, params["stem_w"], 1) + params["stem_b"], 0.0))
    for i in range(config.BLOCKS):
        h = block_forward(h, params["blocks"][i], i, opsel, ksel, expmask, outmask)
    feats = jnp.mean(h, axis=(1, 2))  # global average pool
    # Classifier head on the L1 pallas kernel (differentiated via its
    # custom VJP, which also runs the kernel).
    return matmul(feats, params["head_w"]) + params["head_b"]


def _loss_acc(params, x, y, opsel, ksel, expmask, outmask):
    logits = forward(params, x, opsel, ksel, expmask, outmask)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    loss = jnp.mean(logz - ll)
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return loss, acc


def train_step(flat, m, v, step, x, y, opsel, ksel, expmask, outmask, lr):
    """One Adam step (global-norm-clipped) on the flat parameter vector.

    Returns ``(flat', m', v', loss, acc)``. Adam + clipping is the only
    recipe we found that trains *every* masked subnetwork of the
    supernet stably — SGD+momentum (the paper's RMSProp child setting)
    diverges at large effective widths and stalls at small ones on the
    BN-free proxy (see DESIGN.md §Substitutions). The learning rate is a
    runtime scalar so the rust trainer owns the schedule; masked
    parameters receive zero gradient and therefore never move.
    """

    def loss_fn(f):
        return _loss_acc(unravel(f), x, y, opsel, ksel, expmask, outmask)

    (loss, acc), g = jax.value_and_grad(loss_fn, has_aux=True)(flat)
    gn = jnp.sqrt((g * g).sum())
    g = g * jnp.minimum(1.0, 5.0 / (gn + 1e-9))
    b1, b2, eps = 0.9, 0.999, 1e-8
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    t = step.astype(jnp.float32) + 1.0
    mhat = m / (1 - b1**t)
    vhat = v / (1 - b2**t)
    flat = flat - lr * mhat / (jnp.sqrt(vhat) + eps)
    return flat, m, v, loss, acc


def eval_step(flat, x, y, opsel, ksel, expmask, outmask):
    """Loss and accuracy of the masked subnetwork on one eval batch."""
    return _loss_acc(unravel(flat), x, y, opsel, ksel, expmask, outmask)
