"""Shared configuration constants for the L1/L2 build-time programs.

These constants define the *trainable proxy* supernet (the scaled-down
stand-in for the paper's ImageNet child programs — see DESIGN.md
§Substitutions) and the cost model (paper Table 2). They are exported to
``artifacts/manifest.json`` by ``aot.py`` so the rust coordinator reads a
single source of truth and never hard-codes shapes.
"""

# ---------------------------------------------------------------------------
# Proxy task (synthetic stand-in for ImageNet; see DESIGN.md §Substitutions).
# ---------------------------------------------------------------------------
IMG = 8                # input resolution (IMG x IMG x 3)
NUM_CLASSES = 16
TRAIN_BATCH = 32
EVAL_BATCH = 128

# ---------------------------------------------------------------------------
# Supernet: B switchable IBN / Fused-IBN blocks with mask-encoded decisions.
# ---------------------------------------------------------------------------
STEM_CH = 8
BLOCKS = 5
WIDTHS = [8, 16, 16, 32, 32]     # allocated (multiplier=1.0) output channels
STRIDES = [1, 2, 1, 2, 1]
MAX_EXPANSION = 6                # expansion masks select {3, 6} of this
KMAX = 7                         # allocated depthwise / fused kernel size
KERNEL_SIZES = [3, 5, 7]
CMAX = max(WIDTHS)                       # widest block output
CEXP_MAX = MAX_EXPANSION * CMAX          # widest expanded tensor

# ---------------------------------------------------------------------------
# Cost model (paper Table 2): 3-layer MLP, hidden 256, input feature 394,
# dual heads (latency, area), loss = MSE(area) + LAMBDA * MSE(latency).
# ---------------------------------------------------------------------------
FEATURE_DIM = 394
COST_HIDDEN = 256
COST_LAYERS = 3
COST_BATCH = 128
COST_LR = 1e-3
COST_LAMBDA = 10.0
COST_DROPOUT = 0.1

# ---------------------------------------------------------------------------
# Pallas kernel tiling (L1). Small shapes: blocks clamp to the dimension.
# ---------------------------------------------------------------------------
BLOCK_M = 32
BLOCK_N = 64
BLOCK_K = 64
