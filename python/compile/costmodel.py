"""L2: the NAHAS cost model (paper §3.5.2, Table 2, Eq. 7).

A 3-layer MLP (hidden 256, ReLU, dropout 0.1) over a 394-dim encoding of
the joint (neural-architecture, accelerator) configuration, with two
prediction heads sharing the trunk:

    latency head  f_l(alpha, h)      area head  f_a(h)
    Loss = MSE(area) + lambda * MSE(latency),  lambda = 10   (Eq. 7)

Trained with Adam (lr 1e-3, batch 128) on simulator-labelled samples the
rust coordinator generates — the "labelled data is cheap, use the
simulator farm" setup of the paper.

Two graphs are exported:

  * ``train_step`` — differentiates through the *composable* L1 pallas
    matmul (custom VJP), so the whole optimisation path runs the kernel;
  * ``infer`` — runs the *fused* L1 MLP-trunk kernel (kernels/mlp.py),
    the hot path that replaces the simulator inside oneshot search.

Both are asserted equal to the jnp oracle and to each other in pytest.
"""

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from compile import config
from compile.kernels.matmul import matmul
from compile.kernels.mlp import fused_mlp

F, H = config.FEATURE_DIM, config.COST_HIDDEN


def params_template():
    z = jnp.zeros
    return {
        "w1": z((F, H)),
        "b1": z((H,)),
        "w2": z((H, H)),
        "b2": z((H,)),
        "w3": z((H, H)),
        "b3": z((H,)),
        # Dual heads on the shared trunk (paper: "largely share parameters
        # with only separate parameterization in the prediction heads").
        "wl": z((H, 1)),
        "bl": z((1,)),
        "wa": z((H, 1)),
        "ba": z((1,)),
    }


_TEMPLATE = params_template()
FLAT_TEMPLATE, unravel = ravel_pytree(_TEMPLATE)
PARAM_COUNT = FLAT_TEMPLATE.shape[0]


def init_fn(seed):
    """He-normal init; returns (flat, adam_m, adam_v) all length P."""
    leaves, treedef = jax.tree_util.tree_flatten(_TEMPLATE)
    key = jax.random.PRNGKey(seed)
    out = []
    for i, leaf in enumerate(leaves):
        k = jax.random.fold_in(key, i)
        if leaf.ndim == 1:
            out.append(jnp.zeros_like(leaf))
        else:
            std = (2.0 / leaf.shape[0]) ** 0.5
            out.append(std * jax.random.normal(k, leaf.shape))
    flat, _ = ravel_pytree(jax.tree_util.tree_unflatten(treedef, out))
    return flat, jnp.zeros_like(flat), jnp.zeros_like(flat)


def _trunk_composable(p, x, dropout_key=None):
    """Trunk via the composable pallas matmul (training path)."""
    h = jnp.maximum(matmul(x, p["w1"]) + p["b1"], 0.0)
    h = _dropout(h, dropout_key, 0)
    h = jnp.maximum(matmul(h, p["w2"]) + p["b2"], 0.0)
    h = _dropout(h, dropout_key, 1)
    h = jnp.maximum(matmul(h, p["w3"]) + p["b3"], 0.0)
    h = _dropout(h, dropout_key, 2)
    return h


def _dropout(h, key, layer):
    if key is None:
        return h
    keep = 1.0 - config.COST_DROPOUT
    mask = jax.random.bernoulli(jax.random.fold_in(key, layer), keep, h.shape)
    return h * mask / keep


def _heads(p, h):
    lat = (matmul(h, p["wl"]) + p["bl"])[:, 0]
    area = (matmul(h, p["wa"]) + p["ba"])[:, 0]
    return lat, area


def predict(p, x, dropout_key=None):
    """Composable-kernel prediction (used by train and by tests)."""
    h = _trunk_composable(p, x, dropout_key)
    return _heads(p, h)


def infer(flat, x):
    """Inference via the fused L1 MLP-trunk kernel. Returns (lat, area)."""
    p = unravel(flat)
    h = fused_mlp(x, p["w1"], p["b1"], p["w2"], p["b2"], p["w3"], p["b3"])
    return _heads(p, h)


def train_step(flat, m, v, step, seed, x, y_lat, y_area):
    """One Adam step of Eq. 7. Returns (flat', m', v', loss).

    ``step`` is the 0-based global step (for bias correction), ``seed``
    drives the dropout mask (folded with the step so every batch gets a
    fresh mask).
    """

    def loss_fn(f):
        p = unravel(f)
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        lat, area = predict(p, x, dropout_key=key)
        mse_l = jnp.mean((lat - y_lat) ** 2)
        mse_a = jnp.mean((area - y_area) ** 2)
        return mse_a + config.COST_LAMBDA * mse_l

    loss, g = jax.value_and_grad(loss_fn)(flat)
    b1, b2, eps = 0.9, 0.999, 1e-8
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    t = step.astype(jnp.float32) + 1.0
    mhat = m / (1 - b1**t)
    vhat = v / (1 - b2**t)
    flat = flat - config.COST_LR * mhat / (jnp.sqrt(vhat) + eps)
    return flat, m, v, loss
