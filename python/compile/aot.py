"""AOT-lower every L2 program to HLO **text** + a JSON manifest.

This is the only python entrypoint in the build (``make artifacts``); the
rust coordinator is self-contained afterwards.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every program is lowered with ``return_tuple=True`` so the rust side
always unwraps one tuple. ``manifest.json`` records, per program, the
input/output names, dtypes and shapes, plus the shared configuration
constants — the single source of truth the rust runtime loads.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import config, costmodel, model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text()
    # The HLO text printer ELIDES large literals as `constant({...})`,
    # which the rust-side text parser silently reconstructs as ZEROS.
    # Any graph constant bigger than the print threshold must be rebuilt
    # from iota/arithmetic (see model._kmasks) or passed as an input.
    if "constant({...})" in text:
        raise RuntimeError(
            "exported HLO contains an elided large constant — it would "
            "silently become zeros on the rust side; rebuild it from "
            "iota/arithmetic or pass it as an input"
        )
    return text


def _spec(name, dtype, shape):
    return {"name": name, "dtype": dtype, "shape": list(shape)}


def f32(name, *shape):
    return (_spec(name, "f32", shape), jax.ShapeDtypeStruct(shape, jnp.float32))


def i32(name, *shape):
    return (_spec(name, "i32", shape), jax.ShapeDtypeStruct(shape, jnp.int32))


def quickstart_matmul(x, w):
    from compile.kernels.matmul import matmul_pallas

    return matmul_pallas(x, w)


def build_programs():
    """(name, fn, [(spec, ShapeDtypeStruct)...], [output specs]) tuples."""
    P = model.PARAM_COUNT
    CP = costmodel.PARAM_COUNT
    B, NB = config.TRAIN_BATCH, config.BLOCKS
    EB = config.EVAL_BATCH
    mask_args = [
        f32("opsel", NB, 2),
        f32("ksel", NB, 3),
        f32("expmask", NB, config.CEXP_MAX),
        f32("outmask", NB, config.CMAX),
    ]
    img = (config.IMG, config.IMG, 3)
    progs = []
    progs.append(
        (
            "supernet_init",
            lambda seed: model.init_fn(seed),
            [i32("seed")],
            [_spec(n, "f32", (P,)) for n in ("flat", "m", "v")],
        )
    )
    progs.append(
        (
            "supernet_train",
            model.train_step,
            [
                f32("flat", P),
                f32("m", P),
                f32("v", P),
                i32("step"),
                f32("x", B, *img),
                i32("y", B),
            ]
            + mask_args
            + [f32("lr")],
            [_spec(n, "f32", (P,)) for n in ("flat", "m", "v")]
            + [
                _spec("loss", "f32", ()),
                _spec("acc", "f32", ()),
            ],
        )
    )
    progs.append(
        (
            "supernet_eval",
            model.eval_step,
            [f32("flat", P), f32("x", EB, *img), i32("y", EB)] + mask_args,
            [_spec("loss", "f32", ()), _spec("acc", "f32", ())],
        )
    )
    progs.append(
        (
            "costmodel_init",
            lambda seed: costmodel.init_fn(seed),
            [i32("seed")],
            [_spec(n, "f32", (CP,)) for n in ("flat", "m", "v")],
        )
    )
    progs.append(
        (
            "costmodel_train",
            costmodel.train_step,
            [
                f32("flat", CP),
                f32("m", CP),
                f32("v", CP),
                i32("step"),
                i32("seed"),
                f32("x", config.COST_BATCH, config.FEATURE_DIM),
                f32("y_lat", config.COST_BATCH),
                f32("y_area", config.COST_BATCH),
            ],
            [_spec(n, "f32", (CP,)) for n in ("flat", "m", "v")]
            + [_spec("loss", "f32", ())],
        )
    )
    for bs in (1, 256):
        progs.append(
            (
                f"costmodel_infer_b{bs}",
                costmodel.infer,
                [f32("flat", CP), f32("x", bs, config.FEATURE_DIM)],
                [
                    _spec("lat", "f32", (bs,)),
                    _spec("area", "f32", (bs,)),
                ],
            )
        )
    progs.append(
        (
            "quickstart_matmul",
            quickstart_matmul,
            [f32("x", 16, 16), f32("w", 16, 16)],
            [_spec("out", "f32", (16, 16))],
        )
    )
    return progs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {
        "config": {
            k: getattr(config, k)
            for k in dir(config)
            if k.isupper() and not k.startswith("_")
        },
        "supernet_param_count": model.PARAM_COUNT,
        "costmodel_param_count": costmodel.PARAM_COUNT,
        "programs": {},
    }
    for name, fn, inputs, outputs in build_programs():
        specs = [s for s, _ in inputs]
        shapes = [sd for _, sd in inputs]
        lowered = jax.jit(fn).lower(*shapes)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest["programs"][name] = {
            "file": fname,
            "inputs": specs,
            "outputs": outputs,
        }
        print(f"lowered {name}: {len(text)} chars, {len(specs)} inputs")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest['programs'])} programs")


if __name__ == "__main__":
    main()
