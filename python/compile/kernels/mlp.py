"""L1 Pallas kernel: fused 3-layer MLP trunk (the cost-model hot path).

The paper's cost model (Table 2) is a 3-layer MLP of hidden size 256 over
a 394-dim feature. During oneshot search its *inference* is the inner
loop replacing the accelerator simulator, so the whole trunk

    h = relu(relu(relu(x @ W1 + b1) @ W2 + b2) @ W3 + b3)

is fused into a single pallas kernel: the weights (394*256 + 2*256*256
floats ~ 0.9 MB) are small enough to stay VMEM-resident across the whole
batch, so the kernel tiles only over batch rows and never re-streams the
weights — the compute-intensity argument the paper makes for fused ops,
applied to our own hot path.

``interpret=True`` as everywhere (see matmul.py).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile import config


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref, o_ref):
    """One batch-row tile through the whole trunk; weights VMEM-resident."""
    h = x_ref[...]
    h = jnp.maximum(
        jnp.dot(h, w1_ref[...], preferred_element_type=jnp.float32)
        + b1_ref[...],
        0.0,
    )
    h = jnp.maximum(
        jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)
        + b2_ref[...],
        0.0,
    )
    h = jnp.maximum(
        jnp.dot(h, w3_ref[...], preferred_element_type=jnp.float32)
        + b3_ref[...],
        0.0,
    )
    o_ref[...] = h


def fused_mlp(x, w1, b1, w2, b2, w3, b3, *, bm=None):
    """Fused relu-MLP trunk: ``x [M, F] -> [M, H]`` in one pallas call."""
    m, f = x.shape
    h = w1.shape[1]
    assert w2.shape == (h, h) and w3.shape == (h, h), (w2.shape, w3.shape)
    bm = min(bm or config.BLOCK_M, m)
    mp = ((m + bm - 1) // bm) * bm
    xp = jnp.pad(x, ((0, mp - m), (0, 0)))
    # Biases as [1, H] rows so they broadcast inside the kernel.
    b1r, b2r, b3r = (b.reshape(1, h) for b in (b1, b2, b3))

    whole = lambda i: (0, 0)  # noqa: E731 — weights: one full-tensor block
    out = pl.pallas_call(
        _mlp_kernel,
        grid=(mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, f), lambda i: (i, 0)),
            pl.BlockSpec((f, h), whole),
            pl.BlockSpec((1, h), whole),
            pl.BlockSpec((h, h), whole),
            pl.BlockSpec((1, h), whole),
            pl.BlockSpec((h, h), whole),
            pl.BlockSpec((1, h), whole),
        ],
        out_specs=pl.BlockSpec((bm, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, h), jnp.float32),
        interpret=True,
    )(xp, w1, b1r, w2, b2r, w3, b3r)
    return out[:m]
