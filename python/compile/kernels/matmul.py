"""L1 Pallas kernel: MXU-style tiled matmul with a custom VJP.

The kernel tiles the ``[M, K] @ [K, N]`` product over a ``(M/bm, N/bn,
K/bk)`` grid. Each ``(i, j)`` output tile stays resident in VMEM while the
``k`` grid dimension (innermost, sequential) streams ``bm x bk`` /
``bk x bn`` operand tiles from HBM and accumulates into it — the
K-reduction systolic pass a TPU MXU performs, and exactly the compute
pattern the L3 accelerator simulator costs for regular convolutions (see
DESIGN.md §Hardware-Adaptation).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered through the pallas interpreter into
plain HLO (loops + dynamic slices). Correctness vs the jnp oracle is the
contract; real-TPU performance is estimated analytically in DESIGN.md.

The backward pass re-uses the same kernel (``dx = g @ w^T``, ``dw = x^T @
g``) through ``jax.custom_vjp`` — pallas_call itself has no transpose
rule, and routing the VJP through the kernel keeps the AOT training graph
on the L1 code path.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile import config


def _matmul_kernel(x_ref, w_ref, o_ref, *, k_steps):
    """One (i, j, k) grid step: o_tile += x_tile @ w_tile.

    The output tile is revisited across the sequential ``k`` dimension
    (its index map ignores ``k``), so it acts as the VMEM accumulator: it
    is zeroed on the first k step and accumulated into afterwards.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def matmul_pallas(x, w, *, bm=None, bn=None, bk=None):
    """``x [M, K] @ w [K, N]`` via the tiled pallas kernel (f32).

    Shapes need not be tile-aligned: operands are zero-padded up to the
    tile grid and the result is sliced back. Zero padding is exact for a
    matmul (padded rows/cols contribute zeros).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"matmul inner dims mismatch: {x.shape} @ {w.shape}"
    bm = min(bm or config.BLOCK_M, _ceil_to(m, 8))
    bn = min(bn or config.BLOCK_N, _ceil_to(n, 8))
    bk = min(bk or config.BLOCK_K, _ceil_to(k, 8))

    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    k_steps = kp // bk

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(mp // bm, np_ // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


@jax.custom_vjp
def matmul(x, w):
    """Differentiable tiled-pallas matmul (forward and backward on L1)."""
    return matmul_pallas(x, w)


def _matmul_fwd(x, w):
    return matmul_pallas(x, w), (x, w)


def _matmul_bwd(res, g):
    x, w = res
    dx = matmul_pallas(g, w.T)
    dw = matmul_pallas(x.T, g)
    return dx, dw


matmul.defvjp(_matmul_fwd, _matmul_bwd)
