"""Pure-jnp correctness oracles for the L1 kernels and L2 blocks.

Everything here is the "obviously correct" reference implementation: no
pallas, no masking tricks. pytest (``python/tests/``) asserts the pallas
kernels and the mask-encoded supernet blocks agree with these within
float32 tolerance — the core correctness signal of the compile path.
"""

import jax.numpy as jnp
from jax import lax


def matmul_ref(x, w):
    """Oracle for kernels.matmul: plain f32 matmul."""
    return jnp.matmul(x, w)


def fused_mlp_ref(x, w1, b1, w2, b2, w3, b3):
    """Oracle for kernels.mlp.fused_mlp: 3x (matmul + bias + relu)."""
    h = jnp.maximum(x @ w1 + b1, 0.0)
    h = jnp.maximum(h @ w2 + b2, 0.0)
    return jnp.maximum(h @ w3 + b3, 0.0)


def conv2d_ref(x, w, stride=1):
    """NHWC x HWIO 'same' conv oracle."""
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def dwconv2d_ref(x, w, stride=1):
    """Depthwise 'same' conv oracle; ``w`` is ``[kh, kw, 1, C]``."""
    c = x.shape[-1]
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )


def rmsnorm_ref(h, eps=1e-6):
    """Channel RMSNorm oracle (matches model.rmsnorm_masked on a dense,
    fully-active tensor)."""
    ms = (h * h).mean(axis=-1, keepdims=True)
    return h * lax.rsqrt(ms + eps)


def ibn_block_ref(x, w1, b1, dw, bdw, w2, b2, stride=1, residual=False):
    """Plain (un-masked) inverted-bottleneck block oracle.

    expand 1x1 -> relu -> rmsnorm -> depthwise kxk (stride) -> relu ->
    rmsnorm -> project 1x1, linear output, optional residual.
    ``w1 [cin, cexp]``, ``dw [k, k, 1, cexp]``, ``w2 [cexp, cout]``.
    """
    n, h, w_, cin = x.shape
    hmid = jnp.maximum(x.reshape(-1, cin) @ w1 + b1, 0.0)
    hmid = rmsnorm_ref(hmid.reshape(n, h, w_, -1))
    hmid = rmsnorm_ref(jnp.maximum(dwconv2d_ref(hmid, dw, stride) + bdw, 0.0))
    nh, nw = hmid.shape[1], hmid.shape[2]
    out = hmid.reshape(-1, hmid.shape[-1]) @ w2 + b2
    out = out.reshape(n, nh, nw, -1)
    return out + x if residual else out


def fused_ibn_block_ref(x, wf, bf, w2, b2, stride=1, residual=False):
    """Plain fused-IBN block oracle: full kxk conv -> relu -> rmsnorm ->
    project 1x1. ``wf [k, k, cin, cexp]``, ``w2 [cexp, cout]``.
    """
    n = x.shape[0]
    hmid = rmsnorm_ref(jnp.maximum(conv2d_ref(x, wf, stride) + bf, 0.0))
    nh, nw = hmid.shape[1], hmid.shape[2]
    out = hmid.reshape(-1, hmid.shape[-1]) @ w2 + b2
    out = out.reshape(n, nh, nw, -1)
    return out + x if residual else out
