"""L1 kernel correctness: pallas vs pure-jnp oracle.

Hypothesis sweeps shapes (including tile-unaligned ones) — the CORE
correctness signal for the compile path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.matmul import matmul, matmul_pallas
from compile.kernels.mlp import fused_mlp

jax.config.update("jax_platform_name", "cpu")

DIM = st.integers(min_value=1, max_value=70)


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


class TestMatmul:
    @settings(max_examples=25, deadline=None)
    @given(m=DIM, k=DIM, n=DIM, seed=st.integers(0, 2**31 - 1))
    def test_matches_oracle_over_shapes(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        x, w = rand(rng, m, k), rand(rng, k, n)
        got = matmul_pallas(x, w)
        np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize(
        "m,k,n",
        [(1, 1, 1), (8, 8, 8), (32, 64, 64), (33, 65, 17), (128, 394, 256)],
    )
    def test_matches_oracle_fixed(self, m, k, n):
        rng = np.random.default_rng(0)
        x, w = rand(rng, m, k), rand(rng, k, n)
        got = matmul_pallas(x, w)
        np.testing.assert_allclose(got, x @ w, rtol=1e-4, atol=1e-4)

    def test_explicit_tiling_multi_k_step(self):
        # Force >1 step along every grid dimension.
        rng = np.random.default_rng(1)
        x, w = rand(rng, 64, 96), rand(rng, 96, 48)
        got = matmul_pallas(x, w, bm=16, bn=16, bk=16)
        np.testing.assert_allclose(got, x @ w, rtol=1e-4, atol=1e-4)

    def test_vjp_matches_jnp_grads(self):
        rng = np.random.default_rng(2)
        x, w = rand(rng, 20, 30), rand(rng, 30, 10)

        def f_pallas(a, b):
            return (matmul(a, b) ** 2).sum()

        def f_ref(a, b):
            return ((a @ b) ** 2).sum()

        dx, dw = jax.grad(f_pallas, argnums=(0, 1))(x, w)
        rx, rw = jax.grad(f_ref, argnums=(0, 1))(x, w)
        np.testing.assert_allclose(dx, rx, rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(dw, rw, rtol=1e-3, atol=1e-3)

    def test_zero_and_identity(self):
        eye = np.eye(24, dtype=np.float32)
        rng = np.random.default_rng(3)
        x = rand(rng, 24, 24)
        np.testing.assert_allclose(matmul_pallas(x, eye), x, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(
            matmul_pallas(x, np.zeros_like(x)), np.zeros_like(x), atol=0
        )

    def test_jit_composes(self):
        rng = np.random.default_rng(4)
        x, w = rand(rng, 17, 19), rand(rng, 19, 23)
        got = jax.jit(matmul)(x, w)
        np.testing.assert_allclose(got, x @ w, rtol=1e-4, atol=1e-4)


class TestFusedMlp:
    def _params(self, rng, f, h):
        return (
            rand(rng, f, h),
            rand(rng, h) * 0.1,
            rand(rng, h, h) / np.sqrt(h),
            rand(rng, h) * 0.1,
            rand(rng, h, h) / np.sqrt(h),
            rand(rng, h) * 0.1,
        )

    @settings(max_examples=10, deadline=None)
    @given(m=st.integers(1, 80), seed=st.integers(0, 2**31 - 1))
    def test_matches_oracle_over_batch(self, m, seed):
        rng = np.random.default_rng(seed)
        f, h = 37, 16
        p = self._params(rng, f, h)
        x = rand(rng, m, f)
        got = fused_mlp(x, *p)
        want = ref.fused_mlp_ref(x, *p)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_paper_table2_shape(self):
        # The exact cost-model trunk shape: 394 -> 256 -> 256 -> 256.
        rng = np.random.default_rng(7)
        p = self._params(rng, 394, 256)
        x = rand(rng, 128, 394)
        got = fused_mlp(x, *p)
        want = ref.fused_mlp_ref(x, *p)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_relu_clamps_negative(self):
        rng = np.random.default_rng(8)
        p = self._params(rng, 9, 8)
        x = rand(rng, 5, 9)
        out = np.asarray(fused_mlp(x, *p))
        assert (out >= 0).all()
