"""L2 supernet correctness: mask-encoded blocks vs plain-block oracles.

The AOT supernet encodes every architectural decision as a dense mask
(see model.py). These tests prove each mask is *exactly* the narrower /
smaller-kernel operator it claims to be, so a controller decision vector
means the same network the rust NAS space + simulator reason about.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import config, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def full_masks():
    B = config.BLOCKS
    return (
        np.tile([1.0, 0.0], (B, 1)).astype(np.float32),  # opsel: IBN
        np.tile([0.0, 0.0, 1.0], (B, 1)).astype(np.float32),  # ksel: k=7
        np.ones((B, config.CEXP_MAX), np.float32),
        np.ones((B, config.CMAX), np.float32),
    )


def rand_params(seed=0):
    flat, _, _ = model.init_fn(jnp.int32(seed))
    return model.unravel(flat)


def rand_x(rng, n, hw, c):
    return rng.standard_normal((n, hw, hw, c)).astype(np.float32)


BLOCK0 = 0  # stride 1, cin == cout == 8 -> residual block


class TestKernelSizeMask:
    @pytest.mark.parametrize("k_idx,k", [(0, 3), (1, 5), (2, 7)])
    def test_ibn_kmask_equals_cropped_kernel_stride1(self, k_idx, k):
        """Masked 7x7 depthwise at stride 1 == true kxk depthwise conv."""
        rng = np.random.default_rng(k)
        p = rand_params()
        bp = p["blocks"][BLOCK0]
        opsel, ksel, expmask, outmask = full_masks()
        ksel[BLOCK0] = np.eye(3, dtype=np.float32)[k_idx]
        x = rand_x(rng, 2, config.IMG, config.STEM_CH)

        got = model.block_forward(x, bp, BLOCK0, opsel, ksel, expmask, outmask)

        off = (config.KMAX - k) // 2
        dw_crop = np.asarray(bp["dw"])[off : off + k, off : off + k]
        want = ref.ibn_block_ref(
            x,
            np.asarray(bp["w1"]),
            np.asarray(bp["b1"]),
            dw_crop,
            np.asarray(bp["bdw"]),
            np.asarray(bp["w2"]),
            np.asarray(bp["b2"]),
            stride=1,
            residual=True,
        )
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("k_idx,k", [(0, 3), (1, 5)])
    def test_fused_kmask_equals_cropped_kernel_stride1(self, k_idx, k):
        rng = np.random.default_rng(10 + k)
        p = rand_params(1)
        bp = p["blocks"][BLOCK0]
        opsel, ksel, expmask, outmask = full_masks()
        opsel[BLOCK0] = [0.0, 1.0]
        ksel[BLOCK0] = np.eye(3, dtype=np.float32)[k_idx]
        x = rand_x(rng, 2, config.IMG, config.STEM_CH)

        got = model.block_forward(x, bp, BLOCK0, opsel, ksel, expmask, outmask)

        off = (config.KMAX - k) // 2
        wf_crop = np.asarray(bp["wf"])[off : off + k, off : off + k]
        want = ref.fused_ibn_block_ref(
            x,
            wf_crop,
            np.asarray(bp["bf"]),
            np.asarray(bp["w2f"]),
            np.asarray(bp["b2f"]),
            stride=1,
            residual=True,
        )
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_stride2_block_finite_and_downsamples(self):
        """Stride-2 masked conv is a valid conv (padding alignment may
        differ from a literal kxk 'SAME' conv — documented in model.py)."""
        rng = np.random.default_rng(3)
        p = rand_params(2)
        i = 1  # stride 2 block
        bp = p["blocks"][i]
        opsel, ksel, expmask, outmask = full_masks()
        ksel[i] = [1.0, 0.0, 0.0]
        x = rand_x(rng, 2, config.IMG, CINS_I1 := model.CINS[i])
        got = np.asarray(
            model.block_forward(x, bp, i, opsel, ksel, expmask, outmask)
        )
        assert got.shape == (2, config.IMG // 2, config.IMG // 2, config.WIDTHS[i])
        assert np.isfinite(got).all()


class TestExpansionMask:
    def test_expansion3_equals_sliced_weights(self):
        """expmask selecting 3*cin of the allocated 6*cin lanes == the
        network built with the sliced (narrow) weight matrices."""
        rng = np.random.default_rng(4)
        p = rand_params(3)
        bp = p["blocks"][BLOCK0]
        cin = model.CINS[BLOCK0]
        cexp3 = 3 * cin
        opsel, ksel, expmask, outmask = full_masks()
        expmask[BLOCK0] = 0.0
        expmask[BLOCK0, :cexp3] = 1.0
        x = rand_x(rng, 2, config.IMG, config.STEM_CH)

        got = model.block_forward(x, bp, BLOCK0, opsel, ksel, expmask, outmask)

        want = ref.ibn_block_ref(
            x,
            np.asarray(bp["w1"])[:, :cexp3],
            np.asarray(bp["b1"])[:cexp3],
            np.asarray(bp["dw"])[:, :, :, :cexp3],
            np.asarray(bp["bdw"])[:cexp3],
            np.asarray(bp["w2"])[:cexp3, :],
            np.asarray(bp["b2"]),
            stride=1,
            residual=True,
        )
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


class TestOutputMask:
    def test_masked_lanes_exactly_zero(self):
        rng = np.random.default_rng(5)
        p = rand_params(4)
        i = 1  # stride-2 block: no residual to re-populate masked lanes
        bp = p["blocks"][i]
        opsel, ksel, expmask, outmask = full_masks()
        half = config.WIDTHS[i] // 2
        outmask[i] = 0.0
        outmask[i, :half] = 1.0
        x = rand_x(rng, 2, config.IMG, model.CINS[i])
        got = np.asarray(
            model.block_forward(x, bp, i, opsel, ksel, expmask, outmask)
        )
        assert np.abs(got[..., half : config.WIDTHS[i]]).max() == 0.0
        assert np.abs(got[..., :half]).max() > 0.0


class TestOpSelect:
    def test_opsel_is_convex_switch(self):
        rng = np.random.default_rng(6)
        p = rand_params(5)
        bp = p["blocks"][BLOCK0]
        opsel, ksel, expmask, outmask = full_masks()
        x = rand_x(rng, 2, config.IMG, config.STEM_CH)

        o_ibn = np.asarray(
            model.block_forward(x, bp, BLOCK0, opsel, ksel, expmask, outmask)
        )
        opsel2 = opsel.copy()
        opsel2[BLOCK0] = [0.0, 1.0]
        o_fused = np.asarray(
            model.block_forward(x, bp, BLOCK0, opsel2, ksel, expmask, outmask)
        )
        opsel3 = opsel.copy()
        opsel3[BLOCK0] = [0.5, 0.5]
        o_mix = np.asarray(
            model.block_forward(x, bp, BLOCK0, opsel3, ksel, expmask, outmask)
        )
        # residual x adds to both paths; 0.5/0.5 of (y1+x)+(y2+x) terms:
        # block adds x once after mixing, so mix = 0.5*o_ibn + 0.5*o_fused.
        np.testing.assert_allclose(
            o_mix, 0.5 * o_ibn + 0.5 * o_fused, rtol=1e-4, atol=1e-4
        )
        assert np.abs(o_ibn - o_fused).max() > 1e-3  # paths genuinely differ


class TestTraining:
    def test_init_deterministic(self):
        f1, m1, v1 = model.init_fn(jnp.int32(42))
        f2, m2, _ = model.init_fn(jnp.int32(42))
        f3, _, _ = model.init_fn(jnp.int32(43))
        np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
        assert np.abs(np.asarray(f1) - np.asarray(f3)).max() > 0
        assert np.abs(np.asarray(m1)).max() == 0.0
        assert np.abs(np.asarray(v1)).max() == 0.0

    def test_train_step_reduces_loss(self):
        rng = np.random.default_rng(7)
        flat, m, v = model.init_fn(jnp.int32(0))
        opsel, ksel, expmask, outmask = full_masks()
        x = rand_x(rng, config.TRAIN_BATCH, config.IMG, 3)
        y = rng.integers(0, config.NUM_CLASSES, config.TRAIN_BATCH).astype(
            np.int32
        )
        step = jax.jit(model.train_step)
        losses = []
        for s in range(15):
            flat, m, v, loss, acc = step(
                flat, m, v, jnp.int32(s), x, y, opsel, ksel, expmask, outmask,
                jnp.float32(0.005)
            )
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.7, losses

    def test_eval_matches_train_metrics(self):
        rng = np.random.default_rng(8)
        flat, _, _ = model.init_fn(jnp.int32(1))
        opsel, ksel, expmask, outmask = full_masks()
        x = rand_x(rng, config.EVAL_BATCH, config.IMG, 3)
        y = rng.integers(0, config.NUM_CLASSES, config.EVAL_BATCH).astype(
            np.int32
        )
        loss, acc = model.eval_step(flat, x, y, opsel, ksel, expmask, outmask)
        assert np.isfinite(float(loss))
        assert 0.0 <= float(acc) <= 1.0
