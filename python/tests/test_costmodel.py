"""Cost-model correctness: fused-kernel inference == composable-kernel
training path == jnp oracle; Adam training fits a toy cost surface."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import config, costmodel
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def init():
    return costmodel.init_fn(jnp.int32(0))


class TestConsistency:
    def test_infer_matches_composable_predict(self):
        """Fused L1 trunk (infer path) == composable matmul trunk (train
        path) with dropout off — the two exported graphs agree."""
        flat, _, _ = init()
        rng = np.random.default_rng(0)
        x = rng.standard_normal((32, config.FEATURE_DIM)).astype(np.float32)
        lat_f, area_f = costmodel.infer(flat, x)
        lat_c, area_c = costmodel.predict(costmodel.unravel(flat), x)
        np.testing.assert_allclose(lat_f, lat_c, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(area_f, area_c, rtol=1e-4, atol=1e-4)

    def test_predict_matches_jnp_oracle(self):
        flat, _, _ = init()
        p = costmodel.unravel(flat)
        rng = np.random.default_rng(1)
        x = rng.standard_normal((16, config.FEATURE_DIM)).astype(np.float32)
        h = ref.fused_mlp_ref(
            x, p["w1"], p["b1"], p["w2"], p["b2"], p["w3"], p["b3"]
        )
        want_lat = (h @ p["wl"] + p["bl"])[:, 0]
        want_area = (h @ p["wa"] + p["ba"])[:, 0]
        lat, area = costmodel.predict(p, x)
        np.testing.assert_allclose(lat, want_lat, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(area, want_area, rtol=1e-4, atol=1e-4)


class TestTraining:
    def test_fits_linear_cost_surface(self):
        """A few hundred Adam steps fit a synthetic latency/area surface
        (the same functional form the rust featurizer produces)."""
        flat, m, v = init()
        rng = np.random.default_rng(2)
        wl = rng.standard_normal(config.FEATURE_DIM) * 0.3
        wa = rng.standard_normal(config.FEATURE_DIM) * 0.2
        step_fn = jax.jit(costmodel.train_step)

        def batch():
            x = rng.standard_normal(
                (config.COST_BATCH, config.FEATURE_DIM)
            ).astype(np.float32)
            y_lat = (x @ wl + 0.1 * (x[:, 0] * x[:, 1])).astype(np.float32)
            y_area = (x @ wa).astype(np.float32)
            return x, y_lat, y_area

        losses = []
        for step in range(200):
            x, y_lat, y_area = batch()
            flat, m, v, loss = step_fn(
                flat, m, v, jnp.int32(step), jnp.int32(0), x, y_lat, y_area
            )
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.1, (losses[0], losses[-1])

        # Held-out check through the FUSED inference path.
        x, y_lat, y_area = batch()
        lat, area = costmodel.infer(flat, x)
        lat_err = float(np.mean((np.asarray(lat) - y_lat) ** 2))
        assert lat_err < losses[0], lat_err

    def test_dropout_seed_changes_loss_but_not_shape(self):
        flat, m, v = init()
        rng = np.random.default_rng(3)
        x = rng.standard_normal((config.COST_BATCH, config.FEATURE_DIM)).astype(
            np.float32
        )
        y = rng.standard_normal(config.COST_BATCH).astype(np.float32)
        out1 = costmodel.train_step(
            flat, m, v, jnp.int32(0), jnp.int32(0), x, y, y
        )
        out2 = costmodel.train_step(
            flat, m, v, jnp.int32(0), jnp.int32(1), x, y, y
        )
        assert out1[0].shape == flat.shape
        assert float(out1[3]) != float(out2[3])  # different dropout masks
