"""AOT manifest/lowering sanity: every exported program lowers, the
manifest agrees with the jitted signatures, and the config block carries
what the rust runtime needs."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, config, costmodel, model

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestManifest:
    def test_program_inventory(self):
        progs = aot.build_programs()
        names = {p[0] for p in progs}
        assert {
            "supernet_init",
            "supernet_train",
            "supernet_eval",
            "costmodel_init",
            "costmodel_train",
            "costmodel_infer_b1",
            "costmodel_infer_b256",
            "quickstart_matmul",
        } <= names

    def test_param_counts_positive_and_consistent(self):
        assert model.PARAM_COUNT > 100_000
        assert costmodel.PARAM_COUNT == (
            (config.FEATURE_DIM * config.COST_HIDDEN + config.COST_HIDDEN)
            + 2 * (config.COST_HIDDEN**2 + config.COST_HIDDEN)
            + 2 * (config.COST_HIDDEN + 1)
        )

    def test_quickstart_lowers_to_hlo_text(self):
        progs = {p[0]: p for p in aot.build_programs()}
        name, fn, inputs, outputs = progs["quickstart_matmul"]
        lowered = jax.jit(fn).lower(*[sd for _, sd in inputs])
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text and "HloModule" in text

    @pytest.mark.skipif(
        not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
        reason="artifacts not built (run `make artifacts`)",
    )
    def test_built_manifest_matches_code(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            man = json.load(f)
        assert man["supernet_param_count"] == model.PARAM_COUNT
        assert man["costmodel_param_count"] == costmodel.PARAM_COUNT
        assert man["config"]["FEATURE_DIM"] == config.FEATURE_DIM
        for name, entry in man["programs"].items():
            path = os.path.join(ARTIFACTS, entry["file"])
            assert os.path.exists(path), name
            # Inputs recorded with concrete shapes/dtypes.
            for spec in entry["inputs"]:
                assert spec["dtype"] in ("f32", "i32")
                assert all(isinstance(d, int) for d in spec["shape"])
